"""Dynamic request batcher (paper O5, resource management).

Requests arrive one at a time; executing them one at a time wastes the
vector unit, executing huge batches blows the latency SLO. The batcher
forms batches by a deadline/size policy:

* flush when ``max_batch`` requests are waiting, or
* when the oldest request has waited ``max_delay_s`` (its deadline), and
* pad the batch up to the next power-of-2 bucket so the engine's plan
  cache hits (shape bucketing = compiled-plan reuse, paper O2).

Admission control: a bounded queue — when the system is saturated the
caller sees backpressure instead of unbounded latency (the "balancing
CPU and memory under high concurrency" knob from the paper, adapted).

Requests may carry a :class:`~repro.core.results.RequestContext`. Its
``version_pin`` is the **batch grouping key**: one batch never mixes
requests pinned to different deployment versions, so a batch is always
served end-to-end by a single version even while a hot-swap redeploy is
in flight. Requests whose context deadline has already passed are
expired in the queue (``DeadlineExceeded``) instead of occupying batch
slots.
"""
from __future__ import annotations

import collections
import dataclasses
import inspect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.results import DeadlineExceeded, RequestContext
from repro.obs.sketch import RollingSketch

__all__ = ["BatcherConfig", "DynamicBatcher", "Request", "BatcherClosed"]


class BatcherClosed(RuntimeError):
    """The batcher was shut down with this request still queued or in
    flight — the caller gets a definite error instead of a hung
    ``Request.wait()``."""


@dataclass(frozen=True)
class BatcherConfig:
    max_batch: int = 64
    max_delay_s: float = 0.002
    max_queue: int = 4096               # admission control bound
    num_dispatchers: int = 1


@dataclass
class Request:
    key: Any
    ts: float
    payload: Optional[np.ndarray] = None
    ctx: Optional[RequestContext] = None
    enqueued_at: float = field(default_factory=time.perf_counter)
    done: threading.Event = field(default_factory=threading.Event)
    result: Optional[Any] = None
    error: Optional[Exception] = None

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self.done.wait(timeout):
            raise TimeoutError("request timed out")
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result

    @property
    def group(self):
        """Batch grouping key: requests in one batch must share it."""
        return None if self.ctx is None else self.ctx.version_pin


class DynamicBatcher:
    """Groups requests and dispatches them to ``serve_batch``.

    ``serve_batch(keys, ts, payloads) -> {name: (B,) np.ndarray}``; a
    serve function that also accepts ``ctx=`` receives the batch's shared
    :class:`RequestContext` (version pin) and may return a
    ``FeatureFrame`` — its ``row(i)`` split keeps per-request metadata.
    """

    def __init__(self, serve_batch: Callable,
                 cfg: BatcherConfig = BatcherConfig(), *,
                 tracer=None):
        self.serve_batch = serve_batch
        self.cfg = cfg
        # optional repro.obs.trace.Tracer: queue-wait spans + exemplar
        # trace propagation into the batch context
        self.tracer = tracer
        try:
            self._wants_ctx = "ctx" in inspect.signature(
                serve_batch).parameters
        except (TypeError, ValueError):
            self._wants_ctx = False
        self._q: Deque[Request] = collections.deque()
        # taken from the queue, not yet completed (close() must fail
        # these too); id-keyed because Request is an eq-dataclass
        self._inflight: Dict[int, Request] = {}
        self._lock = threading.Lock()
        self._new = threading.Condition(self._lock)
        self._stop = False
        self.stats = {"batches": 0, "requests": 0, "rejected": 0,
                      "expired": 0, "sum_batch": 0, "max_batch_seen": 0}
        # CLIENT-observed per-request latency (submit -> result), i.e.
        # queueing INCLUDED — the engine-side serve timer cannot see a
        # queue building up in front of it, this sketch can. A rolling
        # sketch (DESIGN.md §14) instead of the old 512-sample deque:
        # bounded memory AND bounded recency at any traffic level
        self._client_lat = RollingSketch(window_s=5.0)
        self._threads = [
            threading.Thread(target=self._dispatch_loop, daemon=True)
            for _ in range(cfg.num_dispatchers)]
        for t in self._threads:
            t.start()

    # ---------------------------------------------------------------- client
    def submit(self, key, ts: float,
               payload: Optional[np.ndarray] = None,
               ctx: Optional[RequestContext] = None) -> Request:
        if ctx is not None and ctx.expired:
            self.stats["expired"] += 1
            raise DeadlineExceeded("deadline expired before enqueue")
        r = Request(key=key, ts=ts, payload=payload, ctx=ctx)
        with self._lock:
            if self._stop:
                raise BatcherClosed("batcher is closed")
            if len(self._q) >= self.cfg.max_queue:
                self.stats["rejected"] += 1
                raise RuntimeError("admission control: queue full")
            self._q.append(r)
            self._new.notify()
        return r

    def __call__(self, key, ts: float,
                 payload: Optional[np.ndarray] = None,
                 timeout: float = 5.0,
                 ctx: Optional[RequestContext] = None) -> Any:
        return self.submit(key, ts, payload, ctx=ctx).wait(timeout)

    # -------------------------------------------------------------- dispatch
    def _take_batch(self) -> List[Request]:
        cfg = self.cfg
        with self._new:
            while not self._q and not self._stop:
                self._new.wait(0.1)
            if self._stop:
                # close() fails whatever is still queued — dispatching it
                # here would race the shutdown (and a stuck serve_batch is
                # exactly what close() must not wait on)
                return []
            # deadline policy: wait for more work until the oldest
            # request's deadline, then take up to max_batch. cfg is
            # re-read each pass so a live retune (``reconfigure``) moves
            # even the deadline of the batch currently forming
            oldest = self._q[0].enqueued_at
            while True:
                cfg = self.cfg
                deadline = oldest + cfg.max_delay_s
                if (len(self._q) >= cfg.max_batch or self._stop
                        or time.perf_counter() >= deadline):
                    break
                self._new.wait(max(deadline - time.perf_counter(), 0.0001))
            if not self._q:
                # another dispatcher drained the queue while we waited
                # (the wait releases the lock)
                return []
            # one group per batch: take the head's group, skip (and keep
            # queued, in order) requests pinned to a different version
            group = self._q[0].group
            out: List[Request] = []
            kept: List[Request] = []
            while self._q and len(out) < cfg.max_batch:
                r = self._q.popleft()
                if r.ctx is not None and r.ctx.expired:
                    r.error = DeadlineExceeded(
                        "deadline expired while queued")
                    r.done.set()
                    self.stats["expired"] += 1
                    continue
                if r.group == group:
                    out.append(r)
                else:
                    kept.append(r)
            for r in reversed(kept):
                self._q.appendleft(r)
            # register as in-flight BEFORE releasing the lock: a close()
            # racing the dequeue must see every request in either the
            # queue or the in-flight set, or its wait() could hang
            self._inflight.update({id(r): r for r in out})
            return out

    def _dispatch_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                if self._stop:
                    return
                continue
            keys = [r.key for r in batch]
            ts = np.asarray([r.ts for r in batch], np.float32)
            # A batch may mix payload and payload-less requests; absent
            # payloads become zero rows (the engine's own no-row default)
            # so one np.stack shape fits all.
            payloads = None
            proto = next((r.payload for r in batch
                          if r.payload is not None), None)
            if proto is not None:
                zero = np.zeros_like(proto)
                payloads = np.stack([r.payload if r.payload is not None
                                     else zero for r in batch])
            tracer = self.tracer
            if tracer is not None:
                # retroactive queue-wait spans: enqueue -> dispatch, per
                # traced request (enqueued_at and the tracer share the
                # perf_counter clock)
                t_disp = time.perf_counter()
                for r in batch:
                    c = r.ctx
                    if (c is not None and c.trace_id
                            and tracer.sampled(c.trace_id)):
                        tracer.record("batch.queue_wait", c.trace_id,
                                      c.parent_span, r.enqueued_at,
                                      t_disp, tags={"batch": len(batch)})
            try:
                if self._wants_ctx:
                    bctx = self._batch_ctx(batch)
                    res = self.serve_batch(keys, ts, payloads, ctx=bctx)
                else:
                    res = self.serve_batch(keys, ts, payloads)
                if hasattr(res, "row"):
                    for i, r in enumerate(batch):
                        row = res.row(i)
                        if r.ctx is not None and r.ctx.trace_id:
                            # the batch frame carries the exemplar's
                            # trace id; each split row gets its OWN
                            # request's id back
                            row.trace_id = r.ctx.trace_id
                        r.result = row
                        r.done.set()
                else:
                    for i, r in enumerate(batch):
                        r.result = {k: v[i] for k, v in res.items()}
                        r.done.set()
            except Exception as e:
                for r in batch:
                    r.error = e
                    r.done.set()
            finally:
                now = time.perf_counter()
                with self._lock:
                    for r in batch:
                        self._inflight.pop(id(r), None)
                        self._client_lat.observe(now - r.enqueued_at)
            self.stats["batches"] += 1
            self.stats["requests"] += len(batch)
            self.stats["sum_batch"] += len(batch)
            self.stats["max_batch_seen"] = max(self.stats["max_batch_seen"],
                                               len(batch))

    def _batch_ctx(self, batch: List[Request]
                   ) -> Optional[RequestContext]:
        """The batch's shared downstream context: the version pin plus —
        when a tracer is attached — an exemplar trace (the first request
        with a sampled trace lends its ``trace_id``/``parent_span``; the
        engine opens ONE serve span per batch, so one request exemplifies
        the whole dispatch)."""
        pin = batch[0].group
        trace_id = parent = None
        tracer = self.tracer
        if tracer is not None:
            ex = next((r.ctx for r in batch
                       if r.ctx is not None and r.ctx.trace_id
                       and tracer.sampled(r.ctx.trace_id)), None)
            if ex is not None:
                trace_id, parent = ex.trace_id, ex.parent_span
        if pin is None and trace_id is None:
            return None
        return RequestContext(version_pin=pin, trace_id=trace_id,
                              parent_span=parent)

    # ------------------------------------------------------------------ tune
    def reconfigure(self, **changes) -> BatcherConfig:
        """Replace batching policy knobs live (control-plane surface).
        ``num_dispatchers`` cannot change (threads are fixed at
        construction). Dispatchers re-read the config per wait pass, so a
        shorter ``max_delay_s`` even shortens the batch currently
        forming. Returns the previous config."""
        if "num_dispatchers" in changes:
            raise ValueError("num_dispatchers is fixed at construction")
        with self._lock:
            prev = self.cfg
            self.cfg = dataclasses.replace(prev, **changes)
            self._new.notify_all()     # wake waiters onto the new policy
            return prev

    # ----------------------------------------------------------------- intro
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._q)

    def client_latency_percentile(self, pct: float) -> float:
        """Percentile of client-observed request latency (enqueue ->
        completion; queueing delay included). NaN until a request has
        completed. This is the load signal the control plane prefers:
        under saturation the serve-side p99 stays flat while THIS one
        grows by the queueing delay."""
        return self._client_lat.percentile(pct)

    def oldest_age_s(self) -> float:
        """Age of the oldest queued request (0 when the queue is empty) —
        the batcher-side queueing-delay signal the knob controller reads."""
        with self._lock:
            if not self._q:
                return 0.0
            return time.perf_counter() - self._q[0].enqueued_at

    def close(self) -> None:
        """Shut down the dispatchers and FAIL whatever is still pending.

        Every queued request — and any request inside a dispatch that did
        not finish within the join grace period (e.g. a blocked
        ``serve_batch``) — has its ``wait()`` raised with
        :class:`BatcherClosed` instead of hanging until timeout. A
        concurrently-completing dispatch may still deliver its result
        first; completion and close-failure race benignly (first write to
        ``done`` wins from the caller's perspective)."""
        with self._lock:
            self._stop = True
            self._new.notify_all()
        for t in self._threads:
            t.join(timeout=1.0)
        with self._lock:
            leftovers = list(self._q) + list(self._inflight.values())
            self._q.clear()
            self._inflight.clear()
        for r in leftovers:
            if not r.done.is_set():
                r.error = BatcherClosed(
                    "batcher closed before this request was served")
                r.done.set()

    @property
    def mean_batch(self) -> float:
        b = self.stats["batches"]
        return self.stats["sum_batch"] / b if b else 0.0
