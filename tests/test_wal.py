"""Write-ahead ingest log (DESIGN.md §12): record framing, rotation,
torn-tail tolerance, TTL truncation, pipeline integration (accepted
events only, 2PC commit as one atomic record), prepare-TTL auto-abort,
and bit-identical replay through a fresh engine."""
import dataclasses
import os
import struct
import time

import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema
from repro.streaming import IngestPipeline, PipelineConfig, StreamBuffer
from repro.streaming.retention import RetentionPolicy
from repro.streaming.wal import (WalConfig, WriteAheadLog, read_dir,
                                 read_segment, resolve_shard)

SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "aux"))

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""


def _batch(n, t0=0.0, seed=0):
    rng = np.random.default_rng(seed)
    keys = [int(k) for k in rng.integers(0, 4, n)]
    ts = (t0 + np.sort(rng.uniform(0, 10.0, n))).astype(np.float32)
    rows = rng.normal(size=(n, 2)).astype(np.float32)
    return keys, ts, rows


# ------------------------------------------------------------------- unit
def test_wal_roundtrip_and_resume(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(WalConfig(dir=d, sync=False))
    k1, t1, r1 = _batch(8, seed=1)
    k2, t2, r2 = _batch(5, t0=20.0, seed=2)
    wal.append(k1, t1, r1)
    wal.append(k2, t2, r2)
    wal.append([], np.zeros(0, np.float32),
               np.zeros((0, 2), np.float32))      # no-op, not a record
    recs = list(wal.replay())
    assert len(recs) == 2
    assert recs[0][0] == k1
    np.testing.assert_array_equal(recs[0][1], t1)
    np.testing.assert_array_equal(recs[1][2], r2)
    assert wal.metrics()["records"] == 2
    assert wal.metrics()["events"] == 13
    wal.close()

    # reopening the same dir resumes numbering; old records survive
    wal2 = WriteAheadLog(WalConfig(dir=d, sync=False))
    k3, t3, r3 = _batch(3, t0=40.0, seed=3)
    wal2.append(k3, t3, r3)
    assert [r[0] for r in wal2.replay()] == [k1, k2, k3]
    wal2.close()


def test_wal_segment_rotation_and_order(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(WalConfig(dir=d, segment_bytes=256, sync=False))
    batches = [_batch(4, t0=i * 100.0, seed=i) for i in range(6)]
    for k, t, r in batches:
        wal.append(k, t, r)
    assert wal.metrics()["rotations"] >= 1
    assert wal.n_segments >= 2
    recs = list(wal.replay())
    assert len(recs) == 6                  # append order across segments
    for (k, t, r), (rk, rt, rr) in zip(batches, recs):
        assert k == rk
        np.testing.assert_array_equal(t, rt)
    wal.close()


def test_wal_truncate_sealed_below_horizon(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(WalConfig(dir=d, segment_bytes=200, sync=False))
    for i in range(6):
        k, t, r = _batch(4, t0=i * 100.0, seed=i)
        wal.append(k, t, r)
    n_before = wal.n_segments
    assert n_before >= 3
    removed = wal.truncate(300.0)          # segments ending < 300 go
    assert removed >= 1
    assert wal.metrics()["truncated_segments"] == removed
    # surviving records all end at/after the horizon minus one batch
    # span; crucially the ACTIVE segment is never truncated
    recs = list(wal.replay())
    assert recs, "truncate must never empty the live log"
    assert wal.n_segments == n_before - removed
    wal.close()


def test_wal_torn_tail_and_corrupt_record_tolerated(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(WalConfig(dir=d, sync=False))
    k1, t1, r1 = _batch(6, seed=1)
    k2, t2, r2 = _batch(6, t0=50.0, seed=2)
    wal.append(k1, t1, r1)
    wal.append(k2, t2, r2)
    wal.close()
    seg = os.path.join(d, sorted(os.listdir(d))[0])

    # torn tail: a half-written third record (SIGKILL mid-append)
    with open(seg, "ab") as f:
        f.write(struct.pack(">II", 9999, 0) + b"half a record")
    assert [r[0] for r in read_segment(seg)] == [k1, k2]

    # corrupt byte INSIDE the second record: replay keeps the prefix
    data = bytearray(open(seg, "rb").read())
    rec1_len = 8 + struct.unpack(">II", bytes(data[:8]))[0]
    data[rec1_len + 12] ^= 0xFF
    with open(seg, "wb") as f:
        f.write(bytes(data))
    recs = read_segment(seg)
    assert len(recs) == 1 and recs[0][0] == k1


def test_wal_unresolved_placeholder_rejected(tmp_path):
    with pytest.raises(ValueError, match="unresolved placeholder"):
        WriteAheadLog(WalConfig(dir=str(tmp_path / "shard-{shard}")))


def test_resolve_shard_substitution(tmp_path):
    cfg = PipelineConfig(
        wal=WalConfig(dir=str(tmp_path / "shard-{shard}" / "events")))
    r3 = resolve_shard(cfg, 3)
    assert "{shard}" not in r3.wal.dir and "shard-3" in r3.wal.dir
    assert "{shard}" in cfg.wal.dir        # template untouched
    assert resolve_shard(r3, 5) is r3      # already resolved: no-op
    assert resolve_shard(None, 1) is None
    plain = PipelineConfig()
    assert resolve_shard(plain, 1) is plain


# -------------------------------------------------------------- pipeline
def test_pipeline_logs_accepted_events_only(tmp_path):
    """Late-dropped events must NOT reach the log — replay through a
    fresh buffer would otherwise resurrect them (fresh frontier accepts
    everything)."""
    eng = Engine(OptFlags())
    eng.create_table(SCHEMA, max_keys=16, capacity=64, bucket_size=8)
    wal_dir = str(tmp_path / "wal")
    pipe = eng.attach_stream(
        "events", PipelineConfig(lateness=1.0,
                                 wal=WalConfig(dir=wal_dir, sync=False)))
    pipe.push(0, 100.0, np.ones(2, np.float32))
    pipe.push(0, 105.0, np.ones(2, np.float32))
    pipe.flush(flush_all=True)
    assert not pipe.push(0, 50.0, np.ones(2, np.float32))   # late: drop
    total = sum(len(k) for k, _t, _r in read_dir(wal_dir))
    assert total == 2
    assert pipe.metrics()["wal_events"] == 2
    eng.close()


def test_pipeline_2pc_commit_is_one_atomic_record(tmp_path):
    eng = Engine(OptFlags())
    eng.create_table(SCHEMA, max_keys=16, capacity=64, bucket_size=8)
    wal_dir = str(tmp_path / "wal")
    pipe = eng.attach_stream(
        "events", PipelineConfig(wal=WalConfig(dir=wal_dir, sync=False)))
    txn = pipe.prepare([0, 1, 2], [10.0, 11.0, 12.0],
                       np.ones((3, 2), np.float32))
    assert txn is not None
    # prepare parked, nothing logged yet: crash here replays as abort
    assert sum(1 for _ in read_dir(wal_dir)) == 0
    pipe.commit_txn(txn)
    recs = list(read_dir(wal_dir))
    assert len(recs) == 1 and len(recs[0][0]) == 3
    # aborted txns never log
    txn2 = pipe.prepare([3], [20.0], np.ones((1, 2), np.float32))
    pipe.abort_txn(txn2)
    assert sum(1 for _ in read_dir(wal_dir)) == 1
    eng.close()


def test_wal_replay_reproduces_features_bit_identically(tmp_path):
    """The acceptance property: ingest -> kill -> replay the log through
    a fresh engine == never died."""
    keys, ts, rows = _batch(120, seed=7)
    wal_dir = str(tmp_path / "wal")

    eng1 = Engine(OptFlags())
    eng1.create_table(SCHEMA, max_keys=16, capacity=256, bucket_size=16)
    pipe1 = eng1.attach_stream(
        "events", PipelineConfig(wal=WalConfig(dir=wal_dir, sync=False)))
    pipe1.push_batch(keys, ts, rows)
    pipe1.flush(flush_all=True)
    eng1.deploy("q", SQL)
    ref = eng1.request("q", list(range(4)), [1000.0] * 4)
    # simulate SIGKILL: no close/drain — the log alone must suffice
    del pipe1

    eng2 = Engine(OptFlags())
    eng2.create_table(SCHEMA, max_keys=16, capacity=256, bucket_size=16)
    pipe2 = eng2.attach_stream("events", PipelineConfig())
    for rkeys, rts, rrows in read_dir(wal_dir):
        pipe2.push_batch(rkeys, rts, rrows)
    pipe2.flush(flush_all=True)
    eng2.deploy("q", SQL)
    got = eng2.request("q", list(range(4)), [1000.0] * 4)
    assert np.array_equal(np.asarray(ref.status), np.asarray(got.status))
    for c in ref.columns:
        assert np.array_equal(np.asarray(ref[c]), np.asarray(got[c])), c
    eng1.close()
    eng2.close()


def test_pipeline_retention_truncates_wal(tmp_path):
    wal_dir = str(tmp_path / "wal")
    eng = Engine(OptFlags())
    eng.create_table(SCHEMA, max_keys=16, capacity=64, bucket_size=8)
    pipe = eng.attach_stream(
        "events",
        PipelineConfig(
            retention=RetentionPolicy(ttl=50.0, every_n_flushes=1),
            wal=WalConfig(dir=wal_dir, segment_bytes=256, sync=False)))
    for i in range(10):
        k, t, r = _batch(4, t0=i * 40.0, seed=i)
        pipe.push_batch(k, t, r)
        pipe.flush(flush_all=True)
    assert pipe.metrics()["wal_truncated_segments"] >= 1
    eng.close()


# ------------------------------------------------------------ prepare TTL
def test_prepare_ttl_auto_aborts_stale_txn():
    """Regression for the stuck-watermark hole: a coordinator that dies
    between prepare and commit must not hold key frontiers forever."""
    b = StreamBuffer(lateness=0.0, prepare_ttl_s=0.05)
    b.push("a", 10.0, np.zeros(1, np.float32))
    txn = b.prepare(["a"], [11.0], np.zeros((1, 1), np.float32))
    assert txn is not None
    # while prepared, the frontier holds at the parked ts
    b.push("a", 20.0, np.zeros(1, np.float32))
    k, ts, _ = b.ready()
    assert 11.0 not in ts.tolist() and 20.0 not in ts.tolist()
    time.sleep(0.08)                       # TTL expires; presumed dead
    # watermark advances again: the held release is free
    k, ts, _ = b.ready()
    assert 20.0 in ts.tolist() or 10.0 in ts.tolist()
    with pytest.raises(ValueError, match="auto-aborted"):
        b.commit(txn)
    assert b.stats.txn_auto_aborted == 1
    # nothing from the zombie txn was staged
    b.push("a", 30.0, np.zeros(1, np.float32))
    k, ts, _ = b.ready()
    assert 11.0 not in ts.tolist()


def test_prepare_ttl_zero_disables_expiry():
    b = StreamBuffer(lateness=0.0, prepare_ttl_s=0.0)
    txn = b.prepare(["a"], [5.0], np.zeros((1, 1), np.float32))
    time.sleep(0.02)
    events = b.commit(txn)                 # still alive: no TTL
    assert len(events) == 1


def test_prepare_ttl_via_sharded_insert(tmp_path):
    """End-to-end: a sharded 2PC insert against pipelines with a prepare
    TTL — normal inserts commit well inside the TTL; a manually parked
    prepare expires and the key's data keeps flowing."""
    from repro.shard import ShardConfig, ShardedEngine
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=16, capacity=64, bucket_size=8)
    facade = se.attach_stream("events", prepare_ttl_s=0.05)
    se.insert("events", [0, 1], [10.0, 10.0], np.ones((2, 2), np.float32))
    se.deploy("q", SQL)
    # park a prepare directly on shard 0's buffer, then let it expire
    pipe0 = facade.pipes[se.shard_of(0)]
    txn = pipe0.prepare([0], [20.0], np.ones((1, 2), np.float32))
    assert txn is not None
    time.sleep(0.08)
    with pytest.raises(ValueError, match="auto-aborted"):
        pipe0.commit_txn(txn)
    # the frontier is free again: later ingest lands and serves
    se.insert("events", [0], [30.0], np.ones((1, 2), np.float32))
    fr = se.request("q", [0], [100.0])
    assert fr.columns["c"].tolist() == [2.0]   # ts 10 + ts 30, no ts 20
    se.close()
