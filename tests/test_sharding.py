"""Sharding rule tables: divisibility fallbacks, per-arch param specs,
cache specs — validated against AbstractMesh (no devices needed)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_abstract_mesh
from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs
from repro.distributed.sharding import (DEFAULT_RULES, batch_specs,
                                        cache_specs_tree, dp_axes,
                                        param_specs, spec_for_leaf)


def mesh_pod():
    return make_abstract_mesh((16, 16), ("data", "model"))


def mesh_multipod():
    return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_dp_axes():
    assert dp_axes(mesh_pod()) == ("data",)
    assert dp_axes(mesh_multipod()) == ("pod", "data")


def test_divisibility_fallback():
    m = mesh_pod()
    # 12 heads*64 = 768 divisible by 16 -> sharded
    assert spec_for_leaf("blocks/0/attn/wq/w", (28, 1536, 768), m) == \
        P(None, "data", "model")
    # vocab 151936 divisible by 16; d 1536 divisible
    assert spec_for_leaf("embed", (151936, 1536), m) == P("model", "data")
    # a dim NOT divisible by the axis is replicated, not rejected
    assert spec_for_leaf("blocks/0/attn/wq/w", (28, 1530, 768), m) == \
        P(None, None, "model")
    # norms replicated
    assert spec_for_leaf("blocks/0/norm1/scale", (28, 1536), m) == P()


def test_expert_sharding_rules():
    m = mesh_pod()
    # jamba: 16 experts | 16 -> EP over data, ff over model
    s = spec_for_leaf("blocks/1/moe/experts/wi", (4, 16, 4096, 14336), m)
    assert s == P(None, "data", None, "model")
    # mixtral: 8 experts, 16 nmid E -> no EP; FSDP d + TP ff
    s = spec_for_leaf("blocks/0/moe/experts/wi", (56, 8, 6144, 16384), m)
    assert s == P(None, None, "data", "model")
    # wo transposed roles
    s = spec_for_leaf("blocks/0/moe/experts/wo", (56, 8, 16384, 6144), m)
    assert s == P(None, None, "model", "data")


def test_no_duplicate_axis_use():
    """A PartitionSpec must never use one mesh axis on two dims."""
    m = mesh_pod()
    for arch in list_archs():
        from repro.launch.steps import params_struct
        cfg = get_config(arch)
        ps = params_struct(cfg)
        specs = param_specs(ps, m)

        def check(path, spec):
            used = []
            for ax in spec:
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                used.extend(axes)
            assert len(used) == len(set(used)), (arch, path, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, s: check(p, s), specs,
            is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divide_every_leaf(arch):
    """Every spec'd axis must divide its dim on both production meshes
    (the exact property jit enforces at lower time)."""
    from repro.launch.steps import params_struct
    cfg = get_config(arch)
    ps = params_struct(cfg)
    for mesh in (mesh_pod(), mesh_multipod()):
        specs = param_specs(ps, mesh)

        def check(leaf, spec):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                n = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % n == 0, (arch, leaf.shape, spec)

        jax.tree_util.tree_map(check, ps, specs,
                               is_leaf=lambda x: hasattr(x, "shape"))
        break  # specs identical across meshes for params


def test_batch_specs_long_context_sp():
    """long_500k (B=1): batch unshardable -> KV cache sequence sharded."""
    from repro.launch.steps import cache_struct
    cfg = get_config("jamba-v0.1-52b")
    m = mesh_pod()
    cs = cache_struct(cfg, 1, SHAPES["long_500k"].seq_len)
    specs = cache_specs_tree(cs, cfg, m, 1)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    kv = [(p, s) for p, s in flat if "k" in str(p[-1]) or "v" in str(p[-1])]
    # attention kv leaves (reps, B, S, H, D): S sharded over data (and,
    # since jamba's 8 kv heads don't divide the 16-wide model axis, the
    # model axis joins the sequence dim too — 256-way SP)
    def s_axes(spec):
        t = tuple(spec)
        if len(t) < 3 or t[2] is None:
            return ()
        return (t[2],) if isinstance(t[2], str) else tuple(t[2])

    found_sp = any("data" in s_axes(s) for _, s in kv if isinstance(s, P))
    assert found_sp, kv


def test_batch_specs_decode_dp():
    cfg = get_config("qwen2-1.5b")
    m = mesh_multipod()
    bs = batch_specs(cfg, m, "decode", 128)
    assert bs["token"] == P(("pod", "data"))
    bs1 = batch_specs(cfg, m, "decode", 1)     # unshardable
    assert bs1["token"] == P(None)
