"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices.

When the real ``hypothesis`` package is unavailable (the container image
pins its deps), a deterministic mini-shim is installed in its place so the
property tests still run: ``@given`` draws ``max_examples`` seeded samples
per strategy and calls the test once per draw. It covers only what the
suite uses (integers / floats / sampled_from, @settings)."""
import os
import sys
import types

# determinism + quiet logs for the whole suite
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _floats(lo, hi):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def _sampled_from(xs):
        xs = list(xs)
        return _Strategy(lambda rng: xs[int(rng.integers(0, len(xs)))])

    def _given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # NOTE: deliberately no functools.wraps — copying __wrapped__
            # would make pytest read the inner signature and demand the
            # strategy parameters as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_table_with_events(n_keys=8, n_events=400, n_cols=3, capacity=128,
                           bucket_size=16, seed=0, enable_preagg=True):
    """A populated events Table + the raw (keys, ts, rows) used."""
    from repro.featurestore.table import Table, TableSchema
    rng = np.random.default_rng(seed)
    schema = TableSchema("events", key_col="k", ts_col="ts",
                         value_cols=tuple(f"c{i}" for i in range(n_cols)))
    t = Table(schema, max_keys=n_keys, capacity=capacity,
              bucket_size=bucket_size, enable_preagg=enable_preagg)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0.0, 1000.0, n_events)).astype(np.float32)
    rows = rng.normal(0, 2, size=(n_events, n_cols)).astype(np.float32)
    t.insert(keys.tolist(), ts.tolist(), rows)
    return t, (keys, ts, rows)
