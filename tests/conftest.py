"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import os

# determinism + quiet logs for the whole suite
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_table_with_events(n_keys=8, n_events=400, n_cols=3, capacity=128,
                           bucket_size=16, seed=0, enable_preagg=True):
    """A populated events Table + the raw (keys, ts, rows) used."""
    from repro.featurestore.table import Table, TableSchema
    rng = np.random.default_rng(seed)
    schema = TableSchema("events", key_col="k", ts_col="ts",
                         value_cols=tuple(f"c{i}" for i in range(n_cols)))
    t = Table(schema, max_keys=n_keys, capacity=capacity,
              bucket_size=bucket_size, enable_preagg=enable_preagg)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0.0, 1000.0, n_events)).astype(np.float32)
    rows = rng.normal(0, 2, size=(n_events, n_cols)).astype(np.float32)
    t.insert(keys.tolist(), ts.tolist(), rows)
    return t, (keys, ts, rows)
