"""Serving runtime: dynamic batcher semantics, feature server e2e,
model server continuous batching, hedged dispatch."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.serving.batcher import BatcherConfig, DynamicBatcher
from repro.serving.server import FeatureServer, ModelServer, ServerConfig, hedged


def echo_serve(keys, ts, payloads):
    return {"k": np.asarray(keys, np.float32),
            "t": np.asarray(ts, np.float32)}


def test_batcher_batches_concurrent_requests():
    b = DynamicBatcher(echo_serve, BatcherConfig(max_batch=8,
                                                 max_delay_s=0.02))
    out = {}

    def client(i):
        out[i] = b(i, float(i))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.close()
    assert all(out[i]["k"] == i for i in range(16))
    assert b.stats["requests"] == 16
    assert b.stats["batches"] < 16                 # actually batched
    assert b.stats["max_batch_seen"] <= 8


def test_batcher_deadline_flush():
    b = DynamicBatcher(echo_serve, BatcherConfig(max_batch=64,
                                                 max_delay_s=0.01))
    t0 = time.perf_counter()
    r = b(1, 1.0)                                   # single request
    dt = time.perf_counter() - t0
    b.close()
    assert r["k"] == 1.0
    assert dt < 0.5                                 # flushed by deadline


def test_batcher_admission_control():
    ev = threading.Event()

    def slow(keys, ts, payloads):
        ev.wait(1.0)
        return echo_serve(keys, ts, payloads)

    b = DynamicBatcher(slow, BatcherConfig(max_batch=4, max_delay_s=0.001,
                                           max_queue=4))
    reqs = []
    rejected = 0
    for i in range(12):
        try:
            reqs.append(b.submit(i, float(i)))
        except RuntimeError:
            rejected += 1
    ev.set()
    for r in reqs:
        r.wait(2.0)
    b.close()
    assert rejected > 0
    assert b.stats["rejected"] == rejected


def test_batcher_mixed_payload_batch():
    """A batch mixing payload and payload-less requests must not crash
    np.stack nor drop payloads: absent ones become zero rows."""
    def serve(keys, ts, payloads):
        assert payloads is not None
        assert payloads.shape[0] == len(keys)
        return {"p": payloads[:, 0]}

    b = DynamicBatcher(serve, BatcherConfig(max_batch=8, max_delay_s=0.05))
    reqs = [b.submit(i, float(i),
                     np.asarray([7.0], np.float32) if i % 2 == 0 else None)
            for i in range(8)]
    outs = [r.wait(5.0) for r in reqs]
    b.close()
    for i, o in enumerate(outs):
        assert float(o["p"]) == (7.0 if i % 2 == 0 else 0.0)


def test_batcher_close_fails_queued_and_inflight_requests():
    """close() must FAIL pending requests (BatcherClosed) instead of
    leaving Request.wait() callers hanging behind a blocked dispatch."""
    from repro.serving.batcher import BatcherClosed
    release = threading.Event()
    entered = threading.Event()

    def blocked(keys, ts, payloads):
        entered.set()
        release.wait(30.0)              # a dispatch loop stuck in serve
        return echo_serve(keys, ts, payloads)

    b = DynamicBatcher(blocked, BatcherConfig(max_batch=1,
                                              max_delay_s=0.001))
    r1 = b.submit(1, 1.0)               # becomes the blocked in-flight batch
    assert entered.wait(5.0)
    r2 = b.submit(2, 2.0)               # stays queued behind it
    t0 = time.perf_counter()
    b.close()
    assert time.perf_counter() - t0 < 5.0   # close didn't wait for serve
    with pytest.raises(BatcherClosed):
        r2.wait(1.0)                    # queued -> failed, not hanging
    with pytest.raises(BatcherClosed):
        r1.wait(1.0)                    # in-flight -> failed too
    with pytest.raises(BatcherClosed):
        b.submit(3, 3.0)                # submit-after-close is an error
    release.set()


def test_batcher_propagates_errors():
    def boom(keys, ts, payloads):
        raise ValueError("boom")

    b = DynamicBatcher(boom, BatcherConfig(max_delay_s=0.001))
    with pytest.raises(ValueError, match="boom"):
        b(1, 1.0)
    b.close()


def test_feature_server_end_to_end():
    from repro.launch.serve import build_engine
    eng = build_engine(2000, 32)
    srv = FeatureServer(eng, "fraud_features",
                        ServerConfig(BatcherConfig(max_batch=16,
                                                   max_delay_s=0.005)))
    outs = {}

    def client(i):
        outs[i] = srv.request(i % 32, 1e6 + i)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.close()
    assert len(outs) == 32
    for o in outs.values():
        assert "amt_sum_10" in o and np.isfinite(o["amt_sum_10"])


def test_model_server_slots_and_decode():
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.launch.steps import init_params
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = ModelServer(cfg, params, batch=4, cache_len=32)
    slots = srv.prefill(np.ones((2, 8), np.int32))
    assert len(slots) == 2
    toks = srv.decode(steps=4)
    assert toks.shape == (4,)
    assert all(len(srv.generated[s]) == 5 for s in slots)  # 1 prefill + 4
    srv.release(slots)
    slots2 = srv.prefill(np.ones((4, 8), np.int32))
    assert len(slots2) == 4
    with pytest.raises(RuntimeError, match="no free slots"):
        srv.prefill(np.ones((1, 8), np.int32))


def test_hedged_dispatch_takes_fast_attempt():
    calls = {"n": 0}
    lock = threading.Lock()

    def call():
        with lock:
            calls["n"] += 1
            me = calls["n"]
        if me == 1:
            time.sleep(0.5)            # first attempt is the straggler
        return me

    v = hedged(call, after_s=0.05)
    assert v == 2                       # hedge won


# ---------------------------------------------------------------------------
# request contexts: version-pin batch grouping, deadlines, serving sessions
# ---------------------------------------------------------------------------

from repro.core.results import (DeadlineExceeded, FeatureFrame,
                                RequestContext)


def test_batcher_groups_by_version_pin():
    """One batch never mixes requests pinned to different versions."""
    batches = []

    def serve(keys, ts, payloads, ctx=None):
        batches.append((None if ctx is None else ctx.version_pin,
                        list(keys)))
        return {"k": np.asarray(keys, np.float32)}

    b = DynamicBatcher(serve, BatcherConfig(max_batch=16, max_delay_s=0.02))
    reqs = [b.submit(pin, float(i), ctx=RequestContext(version_pin=pin))
            for i, pin in enumerate([1, 2] * 8)]
    for r in reqs:
        r.wait(5.0)
    b.close()
    assert len(batches) >= 2
    for pin, ks in batches:              # key == its pin, by construction
        assert pin is not None and all(k == pin for k in ks)


def test_batcher_expires_deadlined_requests():
    ev = threading.Event()

    def slow(keys, ts, payloads):
        ev.wait(1.0)
        return echo_serve(keys, ts, payloads)

    b = DynamicBatcher(slow, BatcherConfig(max_batch=2, max_delay_s=0.001))
    r1 = b.submit(1, 1.0)                       # occupies the dispatcher
    time.sleep(0.05)
    r2 = b.submit(2, 2.0, ctx=RequestContext.with_timeout(0.01))
    time.sleep(0.1)                             # r2's deadline passes queued
    ev.set()
    assert r1.wait(5.0)["k"] == 1.0
    with pytest.raises(DeadlineExceeded):
        r2.wait(5.0)
    assert b.stats["expired"] == 1
    with pytest.raises(DeadlineExceeded):       # pre-expired: rejected at submit
        b.submit(3, 3.0, ctx=RequestContext(deadline=0.0))
    b.close()


def _small_engine():
    from repro.core.engine import Engine
    from repro.core.optimizer import OptFlags
    from repro.featurestore.table import TableSchema
    eng = Engine(OptFlags())
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount",))
    eng.create_table(schema, max_keys=16, capacity=64, bucket_size=8)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 8, 200)
    ts = np.sort(rng.uniform(0, 1000, 200)).astype(np.float32)
    rows = rng.normal(size=(200, 1)).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    return eng, keys, ts


SQL_A = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)"""
SQL_B = SQL_A.replace("20 PRECEDING", "5 PRECEDING")


def test_feature_server_swap_under_load_and_version_pin():
    eng, keys, ts = _small_engine()
    eng.deploy("q", SQL_A)
    # pre-warm every bucket the batcher can form: v1 compiles here, and
    # the redeploy warms the same observed buckets before its swap — so
    # no compile ever lands between the clients and their deadline
    cfg = ServerConfig(BatcherConfig(max_batch=8, max_delay_s=0.002),
                       warm_buckets=(1, 2, 4, 8))
    with FeatureServer(eng, "q", cfg) as srv:
        base = srv.request(int(keys[0]), float(ts.max()) + 1, timeout=30.0)
        assert isinstance(base, FeatureFrame) and base.version == 1
        stop = threading.Event()
        frames, errs = [], []

        def client(seed):
            i = seed
            while not stop.is_set():
                i += 1
                try:
                    frames.append(srv.request(
                        int(keys[i % 8]), float(ts.max()) + 1 + i,
                        timeout=30.0))
                except Exception as e:            # pragma: no cover
                    errs.append(e)
                    return

        threads = [threading.Thread(target=client, args=(s,))
                   for s in (0, 1000, 2000)]
        for t in threads:
            t.start()
        eng.deploy("q", SQL_B)                    # hot swap under live load
        deadline = time.time() + 30.0             # wait for v2 responses
        while time.time() < deadline:
            if any(f.version == 2 for f in list(frames)):
                break
            time.sleep(0.02)
        stop.set()
        for t in threads:
            t.join(10.0)
        assert not errs
        versions = {f.version for f in frames}
        assert versions <= {1, 2} and 2 in versions
        for f in frames:                          # responses never mix schema
            assert set(f.keys()) == {"s", "c"} and f.all_ok

        # pinning routes to the retired version (shadow replay)
        pinned = srv.request(int(keys[0]), float(ts.max()) + 500,
                             timeout=30.0,
                             ctx=RequestContext(version_pin=1,
                                                trace_id="t-123"))
        assert pinned.version == 1 and pinned.trace_id == "t-123"
    srv.close()                                   # idempotent second close
    eng.close()
