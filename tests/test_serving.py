"""Serving runtime: dynamic batcher semantics, feature server e2e,
model server continuous batching, hedged dispatch."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.serving.batcher import BatcherConfig, DynamicBatcher
from repro.serving.server import FeatureServer, ModelServer, ServerConfig, hedged


def echo_serve(keys, ts, payloads):
    return {"k": np.asarray(keys, np.float32),
            "t": np.asarray(ts, np.float32)}


def test_batcher_batches_concurrent_requests():
    b = DynamicBatcher(echo_serve, BatcherConfig(max_batch=8,
                                                 max_delay_s=0.02))
    out = {}

    def client(i):
        out[i] = b(i, float(i))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.close()
    assert all(out[i]["k"] == i for i in range(16))
    assert b.stats["requests"] == 16
    assert b.stats["batches"] < 16                 # actually batched
    assert b.stats["max_batch_seen"] <= 8


def test_batcher_deadline_flush():
    b = DynamicBatcher(echo_serve, BatcherConfig(max_batch=64,
                                                 max_delay_s=0.01))
    t0 = time.perf_counter()
    r = b(1, 1.0)                                   # single request
    dt = time.perf_counter() - t0
    b.close()
    assert r["k"] == 1.0
    assert dt < 0.5                                 # flushed by deadline


def test_batcher_admission_control():
    ev = threading.Event()

    def slow(keys, ts, payloads):
        ev.wait(1.0)
        return echo_serve(keys, ts, payloads)

    b = DynamicBatcher(slow, BatcherConfig(max_batch=4, max_delay_s=0.001,
                                           max_queue=4))
    reqs = []
    rejected = 0
    for i in range(12):
        try:
            reqs.append(b.submit(i, float(i)))
        except RuntimeError:
            rejected += 1
    ev.set()
    for r in reqs:
        r.wait(2.0)
    b.close()
    assert rejected > 0
    assert b.stats["rejected"] == rejected


def test_batcher_mixed_payload_batch():
    """A batch mixing payload and payload-less requests must not crash
    np.stack nor drop payloads: absent ones become zero rows."""
    def serve(keys, ts, payloads):
        assert payloads is not None
        assert payloads.shape[0] == len(keys)
        return {"p": payloads[:, 0]}

    b = DynamicBatcher(serve, BatcherConfig(max_batch=8, max_delay_s=0.05))
    reqs = [b.submit(i, float(i),
                     np.asarray([7.0], np.float32) if i % 2 == 0 else None)
            for i in range(8)]
    outs = [r.wait(5.0) for r in reqs]
    b.close()
    for i, o in enumerate(outs):
        assert float(o["p"]) == (7.0 if i % 2 == 0 else 0.0)


def test_batcher_propagates_errors():
    def boom(keys, ts, payloads):
        raise ValueError("boom")

    b = DynamicBatcher(boom, BatcherConfig(max_delay_s=0.001))
    with pytest.raises(ValueError, match="boom"):
        b(1, 1.0)
    b.close()


def test_feature_server_end_to_end():
    from repro.launch.serve import build_engine
    eng = build_engine(2000, 32)
    srv = FeatureServer(eng, "fraud_features",
                        ServerConfig(BatcherConfig(max_batch=16,
                                                   max_delay_s=0.005)))
    outs = {}

    def client(i):
        outs[i] = srv.request(i % 32, 1e6 + i)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.close()
    assert len(outs) == 32
    for o in outs.values():
        assert "amt_sum_10" in o and np.isfinite(o["amt_sum_10"])


def test_model_server_slots_and_decode():
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.launch.steps import init_params
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = ModelServer(cfg, params, batch=4, cache_len=32)
    slots = srv.prefill(np.ones((2, 8), np.int32))
    assert len(slots) == 2
    toks = srv.decode(steps=4)
    assert toks.shape == (4,)
    assert all(len(srv.generated[s]) == 5 for s in slots)  # 1 prefill + 4
    srv.release(slots)
    slots2 = srv.prefill(np.ones((4, 8), np.int32))
    assert len(slots2) == 4
    with pytest.raises(RuntimeError, match="no free slots"):
        srv.prefill(np.ones((1, 8), np.int32))


def test_hedged_dispatch_takes_fast_attempt():
    calls = {"n": 0}
    lock = threading.Lock()

    def call():
        with lock:
            calls["n"] += 1
            me = calls["n"]
        if me == 1:
            time.sleep(0.5)            # first attempt is the straggler
        return me

    v = hedged(call, after_s=0.05)
    assert v == 2                       # hedge won
