"""End-to-end system behaviour: the paper's full pipeline — events in,
optimized SQL feature computation, model scoring out — plus the engine's
performance-critical properties (plan cache amortisation, vectorised
batching beats row-at-a-time)."""
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import EventStreamConfig, generate_events, make_labels
from repro.featurestore.table import TableSchema
from repro.launch.serve import FEATURE_SQL, build_engine


def test_fraud_pipeline_end_to_end():
    """Figure 4/5 pipeline: stream -> features -> trained scorer -> serve."""
    eng = build_engine(4000, 64)
    ev = EventStreamConfig(n_events=4000, n_keys=64)
    keys, ts, rows = generate_events(ev)
    y = make_labels(keys, ts, rows)

    # offline: materialise training features (point-in-time). Hot Zipf
    # keys overflow the per-key ring (capacity 1024), so the training set
    # is the RETAINED events; labels are matched by timestamp.
    off = eng.query_offline("fraud_features")
    names = sorted(n for n in off if not n.startswith("__"))
    X = np.stack([off[n] for n in names], -1)
    assert 3000 < X.shape[0] <= 4000 and np.isfinite(X).all()
    idx = np.searchsorted(ts, np.asarray(off["__ts"]))
    y = y[idx]

    # train a tiny logistic scorer on the offline features
    Xn = (X - X.mean(0)) / (X.std(0) + 1e-6)
    w = np.zeros(X.shape[1], np.float32)
    b = 0.0
    lr = 1.0
    for _ in range(300):
        p = 1 / (1 + np.exp(-(Xn @ w + b)))
        g = Xn.T @ (p - y) / len(y)
        w -= lr * g.astype(np.float32)
        b -= lr * float(np.mean(p - y))
    auc_like = np.mean(p[y == 1]) - np.mean(p[y == 0])
    assert auc_like > 0.02          # planted signal is recoverable

    # online: deploy the scorer as a PREDICT UDF over the SAME features
    mu, sd = X.mean(0), X.std(0) + 1e-6

    def scorer(params, feats):
        wj, bj = params
        z = ((feats - mu) / sd) @ wj + bj
        return 1 / (1 + jnp.exp(-z))

    eng.register_model("fraud", scorer, (jnp.asarray(w), jnp.asarray(b)))
    sql = FEATURE_SQL.strip().rstrip()
    head, window = sql.split("FROM events")
    q = (head + ", PREDICT(fraud, amt_sum_10, amt_avg_10, amt_max_10, "
         "txn_cnt_10, amt_std_10, lat_avg_100, lon_avg_100, amt_min_100, "
         "amt_max_100, amt_last) AS score FROM events" + window)
    eng.deploy("fraud_scored", q)
    out = eng.request("fraud_scored", keys[:16].tolist(),
                      (ts[:16] + 1e4).tolist())
    assert out["score"].shape == (16,)
    assert np.all((out["score"] >= 0) & (out["score"] <= 1))


def test_vectorised_beats_rowwise():
    """Paper O4: batch execution must beat row-at-a-time by a wide margin."""
    eng_v = build_engine(3000, 64)
    eng_r = build_engine(3000, 64,
                         flags=OptFlags(vectorized=False))
    keys = np.arange(64)
    B = 64
    # warm both plan caches
    eng_v.request("fraud_features", keys[:B].tolist(), [1e6] * B)
    eng_r.request("fraud_features", keys[:B].tolist(), [1e6] * B)

    t0 = time.perf_counter()
    for i in range(3):
        eng_v.request("fraud_features", keys[:B].tolist(), [1e6 + i] * B)
    tv = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng_r.request("fraud_features", keys[:B].tolist(), [2e6] * B)
    tr = time.perf_counter() - t0
    assert tv / 3 < tr, (tv / 3, tr)   # batched step beats 1 rowwise batch


def test_plan_cache_amortises_compilation():
    """Paper O2: repeat queries must be orders faster than first-compile."""
    eng = build_engine(2000, 32)
    keys = list(range(32))
    t0 = time.perf_counter()
    eng.request("fraud_features", keys, [1e6] * 32)       # compile
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for i in range(5):
        eng.request("fraud_features", keys, [1e6 + i] * 32)
    warm = (time.perf_counter() - t0) / 5
    assert warm < cold / 5, (cold, warm)


def test_multi_window_fusion_single_deploy():
    """Two windows, ten aggregates -> exactly two window groups (merged),
    not ten separate scans (paper 'query optimization')."""
    eng = build_engine(1000, 16)
    dep = eng.deployments["fraud_features"]
    assert len(dep.phys.groups) == 2
    total_aggs = sum(len(g.slots) for g in dep.phys.groups)
    assert total_aggs >= 8            # CSE may share, fusion must group
