"""Distributed-path integration: multi-pod train step with and without
int8-compressed cross-pod gradient all-reduce, executed for REAL on an
8-device (2 pods × 2 data × 2 model) placeholder mesh in a subprocess
(so the 8-device XLA flag never leaks into this test process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.configs.registry import get_config
    from repro.configs.base import reduced
    from repro.launch.steps import init_params, make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                          0, cfg.vocab_size),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                           0, cfg.vocab_size)}
    out = {}
    for compress in (False, True):
        p2, o2 = params, adamw_init(params, ocfg)
        step = jax.jit(make_train_step(cfg, ocfg, mesh=mesh,
                                       compress_crosspod=compress))
        with mesh:
            losses = []
            for _ in range(3):
                p2, o2, m = step(p2, o2, batch)
                losses.append(float(m["loss"]))
        out[str(compress)] = {"losses": losses,
                              "gnorm": float(m["grad_norm"])}
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_multipod_train_step_with_int8_crosspod_reduce():
    from repro.compat import HAS_PARTIAL_MANUAL
    if not HAS_PARTIAL_MANUAL:
        pytest.skip("partially-manual shard_map (pod subgroup) is not "
                    "lowerable by this jax/XLA version")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    plain, comp = out["False"], out["True"]
    # step-0 loss is pre-update: must match exactly; the compressed
    # trajectory must track the uncompressed one (int8 quantization noise
    # only) and train (loss decreasing)
    assert plain["losses"][0] == pytest.approx(comp["losses"][0], rel=1e-5)
    assert comp["losses"][-1] < comp["losses"][0]
    assert plain["losses"][-1] == pytest.approx(comp["losses"][-1],
                                                rel=2e-2)
