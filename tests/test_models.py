"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one
forward/train step + one decode step on CPU, asserting shapes + no NaNs."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, input_specs, param_count, reduced
from repro.configs.registry import get_config, list_archs
from repro.models import encdec, frontend, lm

ARCHS = list_archs()


def _reduced_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "targets": jnp.asarray(np.roll(toks, -1, 1))}
    if cfg.frontend:
        batch["embeds"] = frontend.stub_frontend(
            jax.random.PRNGKey(1), cfg, B)
    if cfg.is_encdec:
        batch["enc_embeds"] = frontend.stub_audio_frames(
            jax.random.PRNGKey(2), cfg, B, n_frames=S)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """One real optimizer step on the reduced config: loss finite+decreases
    direction sane, params updated, grads flow to every leaf."""
    from repro.launch.steps import init_params, make_train_step
    from repro.optim.adamw import AdamWConfig, adamw_init
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          schedule="constant")
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _reduced_batch(cfg)
    p1, o1, m1 = step(params, opt, batch)
    assert np.isfinite(m1["loss"]), arch
    assert float(m1["loss"]) > 0
    # a second step on the same batch must reduce the loss (sanity)
    p2, o2, m2 = step(p1, o1, batch)
    assert float(m2["loss"]) < float(m1["loss"]), arch
    # params actually changed
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, p1)
    assert max(jax.tree_util.tree_leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    """Prefill+decode path: shapes, finiteness, cache threading."""
    cfg = reduced(get_config(arch))
    B, S, steps = 2, 8, 3
    if cfg.is_encdec:
        params = encdec.init_encdec(jax.random.PRNGKey(0), cfg)
        enc_in = frontend.stub_audio_frames(jax.random.PRNGKey(1), cfg, B,
                                            n_frames=S)
        enc_out = encdec.encode(params, cfg, enc_in)
        toks = jnp.ones((B, S), jnp.int32)
        logits, caches = encdec.dec_prefill(params, cfg, enc_out, toks,
                                            cache_len=S + steps)
        assert logits.shape == (B, cfg.vocab_size)
        pos = jnp.full((B,), S, jnp.int32)
        for i in range(steps):
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            logits, caches = encdec.dec_decode_step(
                params, cfg, enc_out, caches, tok, pos + i)
            assert np.isfinite(np.asarray(logits)).all(), arch
        return
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.ones((B, S), jnp.int32)
    emb = (frontend.stub_frontend(jax.random.PRNGKey(1), cfg, B)
           if cfg.frontend else None)
    cache_len = S + steps + (cfg.frontend_len if cfg.frontend else 0)
    logits, caches = lm.prefill(params, cfg, toks, cache_len, emb)
    assert logits.shape == (B, cfg.vocab_size)
    S_eff = S + (cfg.frontend_len if cfg.frontend else 0)
    pos = jnp.full((B,), S_eff, jnp.int32)
    for i in range(steps):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, tok, pos + i)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    """Every (arch × shape) cell has well-defined dry-run input specs."""
    cfg = get_config(arch)
    for shape in SHAPES:
        specs = input_specs(cfg, shape)
        assert specs, (arch, shape)
        for name, s in specs.items():
            assert all(d > 0 for d in s.shape), (arch, shape, name)


def test_prefill_decode_equals_full_forward():
    """Incremental decoding must reproduce teacher-forced logits."""
    cfg = reduced(get_config("qwen2-1.5b"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = lm.forward_train(params, cfg, toks)
    # prefill the first 6, decode the rest one by one
    cut = 6
    logits, caches = lm.prefill(params, cfg, toks[:, :cut], cache_len=S)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, cut - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(cut, S):
        pos = jnp.full((B,), i, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, toks[:, i],
                                        pos)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-3, atol=2e-3, err_msg=f"pos {i}")


def test_mamba_decode_equals_prefill_state():
    """SSM: step-by-step decode == chunked prefill (SSD duality)."""
    cfg = reduced(get_config("mamba2-780m"))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = lm.forward_train(params, cfg, toks)
    logits, caches = lm.prefill(params, cfg, toks[:, :6], cache_len=S)
    for i in range(6, S):
        pos = jnp.full((B,), i, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, toks[:, i], pos)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, i]),
                                   rtol=5e-3, atol=5e-3, err_msg=f"pos {i}")


def test_sliding_window_ring_cache():
    """Mixtral-style rolling KV ring: decode far past the window size must
    equal full attention restricted to the window. capacity_factor is
    raised so MoE token-dropping (a train-vs-decode semantic difference by
    design) cannot mask the attention comparison."""
    base = reduced(get_config("mixtral-8x22b"))
    cfg = dataclasses.replace(
        base, sliding_window=8,
        moe=dataclasses.replace(base.moe, capacity_factor=32.0))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = lm.forward_train(params, cfg, toks)   # SWA inside
    logits, caches = lm.prefill(params, cfg, toks[:, :8], cache_len=8)
    for i in range(8, S):
        pos = jnp.full((B,), i, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, toks[:, i], pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]),
            rtol=2e-2, atol=2e-2, err_msg=f"pos {i}")


def test_param_count_matches_actual():
    from repro.launch.steps import params_struct
    for arch in ARCHS:
        cfg = get_config(arch)
        ps = params_struct(cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(ps))
        analytic = param_count(cfg)
        assert abs(analytic - actual) / actual < 0.01, (
            arch, analytic, actual)


def test_moe_grouped_dispatch_equals_global():
    """Per-DP-shard dispatch groups (moe_groups>1) must produce
    bit-identical outputs to the global dispatch when capacity admits
    every token (only the load-balance regularizer becomes local)."""
    from repro.models import moe as M
    base = reduced(get_config("mixtral-8x22b"))
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=64.0))
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    y1, m1 = M.apply_moe(p, x, cfg)
    y2, m2 = M.apply_moe(p, x, dataclasses.replace(cfg, moe_groups=4))
    assert float(m1["moe_drop_frac"]) == 0.0
    assert float(m2["moe_drop_frac"]) == 0.0
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # non-divisible group count falls back to global dispatch
    y3, _ = M.apply_moe(p, x, dataclasses.replace(cfg, moe_groups=7))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y3))


def test_moe_routing_flop_honesty():
    """Dispatch slab is (E, cap, d) with cap ≈ T·topk·cf/E — active-params
    compute, not dense all-experts."""
    from repro.models.moe import expert_capacity
    from repro.configs.base import MoEConfig
    moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                    capacity_factor=1.25)
    cap = expert_capacity(1024, moe)
    assert cap >= 1024 * 2 * 1.25 / 8
    assert cap <= 1024  # far below the dense all-experts T
