"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes per the assignment."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from conftest import make_table_with_events


# ---------------------------------------------------------------------------
# window_agg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_keys,capacity,n_cols", [
    (4, 64, 1), (8, 128, 3), (3, 256, 2)])
@pytest.mark.parametrize("rows_prec,range_prec", [
    (10, None), (None, 50.0), (31, None)])
def test_window_agg_pallas_vs_ref(n_keys, capacity, n_cols, rows_prec,
                                  range_prec):
    from repro.kernels.window_agg import window_agg_pallas
    t, (keys, ts, rows) = make_table_with_events(
        n_keys=n_keys, n_events=capacity * 2, n_cols=n_cols,
        capacity=capacity, bucket_size=16, seed=42)
    st = t.state
    B = 16
    rng = np.random.default_rng(1)
    req_key = jnp.asarray(rng.integers(0, n_keys, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(100, 1200, B)), jnp.float32)

    kw = dict(rows_preceding=rows_prec, range_preceding=range_prec)
    out_p = window_agg_pallas(st.values, st.ts, st.total, req_key, req_ts,
                              interpret=True, **kw)
    out_r = ref.window_agg_ref(st.values, st.ts, st.total, req_key, req_ts,
                               **kw)
    assert set(out_p) == set(out_r)
    for name in out_r:
        np.testing.assert_allclose(np.asarray(out_p[name]),
                                   np.asarray(out_r[name]),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_window_agg_fields_subset():
    from repro.kernels.window_agg import window_agg_pallas
    t, _ = make_table_with_events(n_keys=4, n_events=100, capacity=64)
    st = t.state
    req_key = jnp.asarray([0, 1], jnp.int32)
    req_ts = jnp.asarray([500.0, 900.0], jnp.float32)
    fields = ("sum", "max")
    out = window_agg_pallas(st.values, st.ts, st.total, req_key, req_ts,
                            rows_preceding=8, fields=fields, interpret=True)
    assert set(out) == set(fields)


# ---------------------------------------------------------------------------
# preagg_window
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity,bucket", [(64, 8), (128, 16), (256, 64)])
@pytest.mark.parametrize("rows_prec,range_prec", [
    (20, None), (None, 100.0), (120, None)])
def test_preagg_window_pallas_vs_ref(capacity, bucket, rows_prec,
                                     range_prec):
    from repro.kernels.preagg_window import preagg_window_pallas
    t, _ = make_table_with_events(n_keys=6, n_events=capacity * 3,
                                  capacity=capacity, bucket_size=bucket,
                                  seed=7)
    st, pa = t.state, t.preagg
    B = 8
    rng = np.random.default_rng(3)
    req_key = jnp.asarray(rng.integers(0, 6, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(200, 1500, B)), jnp.float32)
    kw = dict(bucket_size=bucket, rows_preceding=rows_prec,
              range_preceding=range_prec)
    out_p = preagg_window_pallas(st.values, st.ts, st.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, interpret=True, **kw)
    out_r = ref.preagg_window_ref(st.values, st.ts, st.total, pa.sum,
                                  pa.sumsq, pa.min, pa.max, pa.count,
                                  req_key, req_ts, **kw)
    for name in out_r:
        np.testing.assert_allclose(np.asarray(out_p[name]),
                                   np.asarray(out_r[name]),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_preagg_equals_naive_window():
    """Paper Eq. 2: the pre-aggregated path must equal the naive scan."""
    t, _ = make_table_with_events(n_keys=5, n_events=300, capacity=128,
                                  bucket_size=16, seed=11)
    st, pa = t.state, t.preagg
    B = 12
    rng = np.random.default_rng(5)
    req_key = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(0, 1200, B)), jnp.float32)
    naive = ref.window_agg_ref(st.values, st.ts, st.total, req_key, req_ts,
                               rows_preceding=40)
    fast = ref.preagg_window_ref(st.values, st.ts, st.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, bucket_size=16,
                                 rows_preceding=40)
    for name in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(np.asarray(fast[name]),
                                   np.asarray(naive[name]),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 4, 64),      # MHA
    (1, 128, 128, 8, 2, 64),      # GQA 4x
    (2, 64, 128, 4, 1, 32),       # MQA, cross lengths
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(B, Sq, Sk, Hq, Hkv, D, causal,
                                       window, dtype):
    if not causal and Sq != Sk:
        pytest.skip("non-causal cross shape covered separately")
    from repro.kernels.flash_attention import flash_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out_p = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                   interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (4, 128, 4, 4, 64), (2, 256, 8, 2, 64), (3, 64, 4, 1, 32)])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_pallas_vs_ref(B, S, Hq, Hkv, D, window, dtype):
    from repro.kernels.decode_attention import decode_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(2).integers(1, S + 1, B), jnp.int32)
    out_p = decode_attention_pallas(q, kc, vc, lengths, window=window,
                                    interpret=True)
    out_r = ref.decode_attention_ref(q, kc, vc, lengths, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 2, 32), (1, 64, 256, 8, 2, 32)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
@pytest.mark.parametrize("unroll", [False, True])
def test_flash_attention_xla_streaming_vs_ref(B, Sq, Sk, Hq, Hkv, D,
                                              causal, window, unroll):
    """The streaming online-softmax (dry-run lowering of the flash kernel)
    must equal the dense reference."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32)
    out_s = ref.flash_attention_xla(q, k, v, causal=causal, window=window,
                                    block_k=64, unroll=unroll)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,window,steps", [(16, 8, 20), (8, 8, 12)])
def test_decode_attention_ring_vs_prefix(S, window, steps):
    """Ring-layout decode == prefix-layout decode on the same history."""
    B, Hq, Hkv, D = 2, 4, 2, 16
    rng = jax.random.PRNGKey(9)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    hist_k = jax.random.normal(ks[1], (B, steps, Hkv, D), jnp.float32)
    hist_v = jax.random.normal(ks[2], (B, steps, Hkv, D), jnp.float32)
    pos = steps - 1
    # prefix layout: last `window` live entries, aligned at [0, steps)
    kp = jnp.pad(hist_k, ((0, 0), (0, max(0, S - steps)), (0, 0), (0, 0)))[:, :max(S, steps)]
    vp = jnp.pad(hist_v, ((0, 0), (0, max(0, S - steps)), (0, 0), (0, 0)))[:, :max(S, steps)]
    lengths = jnp.full((B,), steps, jnp.int32)
    want = ref.decode_attention_ref(q, kp[:, :steps], vp[:, :steps],
                                    lengths, window=window)
    # ring layout: entry for position t at slot t % S
    kr = jnp.zeros((B, S, Hkv, D), jnp.float32)
    vr = jnp.zeros((B, S, Hkv, D), jnp.float32)
    for t in range(steps):
        kr = kr.at[:, t % S].set(hist_k[:, t])
        vr = vr.at[:, t % S].set(hist_v[:, t])
    got = ref.decode_attention_ref(q, kr, vr,
                                   jnp.full((B,), pos, jnp.int32),
                                   window=window, ring=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # pallas kernel agrees in ring mode too
    from repro.kernels.decode_attention import decode_attention_pallas
    got_p = decode_attention_pallas(q, kr, vr,
                                    jnp.full((B,), pos, jnp.int32),
                                    window=window, ring=True,
                                    interpret=True, block_k=8)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_flash_last_row():
    """Decoding token t must equal row t of full flash attention."""
    B, S, H, D = 2, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q_full = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    full = ref.flash_attention_ref(q_full, k, v, causal=True)
    lengths = jnp.full((B,), S, jnp.int32)
    dec = ref.decode_attention_ref(q_full[:, -1], k, v, lengths)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)
