"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes per the assignment."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from conftest import make_table_with_events


# ---------------------------------------------------------------------------
# window_agg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_keys,capacity,n_cols", [
    (4, 64, 1), (8, 128, 3), (3, 256, 2)])
@pytest.mark.parametrize("rows_prec,range_prec", [
    (10, None), (None, 50.0), (31, None)])
def test_window_agg_pallas_vs_ref(n_keys, capacity, n_cols, rows_prec,
                                  range_prec):
    from repro.kernels.window_agg import window_agg_pallas
    t, (keys, ts, rows) = make_table_with_events(
        n_keys=n_keys, n_events=capacity * 2, n_cols=n_cols,
        capacity=capacity, bucket_size=16, seed=42)
    st = t.state
    B = 16
    rng = np.random.default_rng(1)
    req_key = jnp.asarray(rng.integers(0, n_keys, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(100, 1200, B)), jnp.float32)

    kw = dict(rows_preceding=rows_prec, range_preceding=range_prec)
    out_p = window_agg_pallas(st.values, st.ts, st.total, req_key, req_ts,
                              interpret=True, **kw)
    out_r = ref.window_agg_ref(st.values, st.ts, st.total, req_key, req_ts,
                               **kw)
    assert set(out_p) == set(out_r)
    for name in out_r:
        np.testing.assert_allclose(np.asarray(out_p[name]),
                                   np.asarray(out_r[name]),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_window_agg_fields_subset():
    from repro.kernels.window_agg import window_agg_pallas
    t, _ = make_table_with_events(n_keys=4, n_events=100, capacity=64)
    st = t.state
    req_key = jnp.asarray([0, 1], jnp.int32)
    req_ts = jnp.asarray([500.0, 900.0], jnp.float32)
    fields = ("sum", "max")
    out = window_agg_pallas(st.values, st.ts, st.total, req_key, req_ts,
                            rows_preceding=8, fields=fields, interpret=True)
    assert set(out) == set(fields)


# ---------------------------------------------------------------------------
# fused_window (single-scan multi-window)
# ---------------------------------------------------------------------------

# mixed ROWS/RANGE spec table with per-spec field masks
FUSED_SPEC_ROWS = (10, None, 31, None)
FUSED_SPEC_RANGES = (None, 50.0, None, 400.0)
FUSED_SPEC_FIELDS = (
    ("sum", "count", "max"),
    ("sum", "sumsq", "count"),
    ("sum", "sumsq", "count", "min", "max", "first", "last"),
    ("min", "first", "last", "count"),
)


def _fused_setup(seed=13, with_mask=False):
    t, _ = make_table_with_events(n_keys=5, n_events=300, n_cols=3,
                                  capacity=128, bucket_size=16, seed=seed)
    st = t.state
    B = 12
    rng = np.random.default_rng(3)
    req_key = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(100, 1300, B)), jnp.float32)
    mask = (st.values[:, :, 0] > 0) if with_mask else None
    return st, req_key, req_ts, mask


@pytest.mark.parametrize("assume_latest", [False, True])
@pytest.mark.parametrize("with_mask", [False, True])
def test_fused_window_pallas_vs_ref(assume_latest, with_mask):
    from repro.kernels.fused_window import fused_window_pallas
    st, req_key, req_ts, mask = _fused_setup(with_mask=with_mask)
    kw = dict(spec_rows=FUSED_SPEC_ROWS, spec_ranges=FUSED_SPEC_RANGES,
              spec_fields=FUSED_SPEC_FIELDS, evt_mask=mask,
              assume_latest=assume_latest)
    out_p = fused_window_pallas(st.values, st.ts, st.total, req_key,
                                req_ts, interpret=True, **kw)
    out_r = ref.fused_window_ref(st.values, st.ts, st.total, req_key,
                                 req_ts, **kw)
    assert set(out_p) == set(out_r)
    for name in out_r:
        np.testing.assert_allclose(np.asarray(out_p[name]),
                                   np.asarray(out_r[name]),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


@pytest.mark.parametrize("with_mask", [False, True])
def test_fused_window_matches_per_group_window_agg(with_mask):
    """Every spec row of the fused output must equal an independent
    single-window ``window_agg_ref`` call with the same frame/fields —
    fusing may share the scan, never change the answer."""
    st, req_key, req_ts, mask = _fused_setup(with_mask=with_mask)
    fused = ref.fused_window_ref(
        st.values, st.ts, st.total, req_key, req_ts,
        spec_rows=FUSED_SPEC_ROWS, spec_ranges=FUSED_SPEC_RANGES,
        spec_fields=FUSED_SPEC_FIELDS, evt_mask=mask)
    for s in range(len(FUSED_SPEC_ROWS)):
        per = ref.window_agg_ref(
            st.values, st.ts, st.total, req_key, req_ts,
            rows_preceding=FUSED_SPEC_ROWS[s],
            range_preceding=FUSED_SPEC_RANGES[s],
            evt_mask=mask, fields=FUSED_SPEC_FIELDS[s])
        for f in FUSED_SPEC_FIELDS[s]:
            got = (fused["count"][:, s] if f == "count"
                   else fused[f][:, s, :])
            np.testing.assert_allclose(np.asarray(got), np.asarray(per[f]),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"spec{s}:{f}")


def test_fused_window_empty_windows_and_field_zeroing():
    """Requests before any event: empty windows (count 0, min POS_INF —
    parity with window_agg_ref's raw outputs); and fields a spec did not
    request are exactly zero on every backend."""
    from repro.kernels.fused_window import fused_window_pallas
    st, req_key, _, _ = _fused_setup()
    req_ts = jnp.full((12,), -100.0, jnp.float32)   # before all events
    kw = dict(spec_rows=(5, None), spec_ranges=(None, 30.0),
              spec_fields=(("sum", "count", "min"), ("count",)),
              assume_latest=False)
    for out in (ref.fused_window_ref(st.values, st.ts, st.total, req_key,
                                     req_ts, **kw),
                fused_window_pallas(st.values, st.ts, st.total, req_key,
                                    req_ts, interpret=True, **kw)):
        assert np.all(np.asarray(out["count"]) == 0.0)
        assert np.all(np.asarray(out["sum"][:, 0]) == 0.0)
        # empty window min stays POS_INF for the requesting spec ...
        assert np.all(np.asarray(out["min"][:, 0]) > 1e38)
        # ... and is exactly zero for the spec that never asked for it
        assert np.all(np.asarray(out["min"][:, 1]) == 0.0)
        assert np.all(np.asarray(out["sum"][:, 1]) == 0.0)


def test_fused_window_spec_validation():
    st, req_key, req_ts, _ = _fused_setup()
    with pytest.raises(ValueError, match="exactly one"):
        ref.fused_window_ref(st.values, st.ts, st.total, req_key, req_ts,
                             spec_rows=(5, 7), spec_ranges=(None, 30.0),
                             spec_fields=(("sum",), ("sum",)))
    with pytest.raises(ValueError, match="lengths"):
        ref.fused_window_ref(st.values, st.ts, st.total, req_key, req_ts,
                             spec_rows=(5,), spec_ranges=(None, 30.0),
                             spec_fields=(("sum",),))


# ---------------------------------------------------------------------------
# preagg_window
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity,bucket", [(64, 8), (128, 16), (256, 64)])
@pytest.mark.parametrize("rows_prec,range_prec", [
    (20, None), (None, 100.0), (120, None)])
def test_preagg_window_pallas_vs_ref(capacity, bucket, rows_prec,
                                     range_prec):
    from repro.kernels.preagg_window import preagg_window_pallas
    t, _ = make_table_with_events(n_keys=6, n_events=capacity * 3,
                                  capacity=capacity, bucket_size=bucket,
                                  seed=7)
    st, pa = t.state, t.preagg
    B = 8
    rng = np.random.default_rng(3)
    req_key = jnp.asarray(rng.integers(0, 6, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(200, 1500, B)), jnp.float32)
    kw = dict(bucket_size=bucket, rows_preceding=rows_prec,
              range_preceding=range_prec)
    out_p = preagg_window_pallas(st.values, st.ts, st.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, interpret=True, **kw)
    out_r = ref.preagg_window_ref(st.values, st.ts, st.total, pa.sum,
                                  pa.sumsq, pa.min, pa.max, pa.count,
                                  req_key, req_ts, **kw)
    for name in out_r:
        np.testing.assert_allclose(np.asarray(out_p[name]),
                                   np.asarray(out_r[name]),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_preagg_equals_naive_window():
    """Paper Eq. 2: the pre-aggregated path must equal the naive scan."""
    t, _ = make_table_with_events(n_keys=5, n_events=300, capacity=128,
                                  bucket_size=16, seed=11)
    st, pa = t.state, t.preagg
    B = 12
    rng = np.random.default_rng(5)
    req_key = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(0, 1200, B)), jnp.float32)
    naive = ref.window_agg_ref(st.values, st.ts, st.total, req_key, req_ts,
                               rows_preceding=40)
    fast = ref.preagg_window_ref(st.values, st.ts, st.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, bucket_size=16,
                                 rows_preceding=40)
    for name in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(np.asarray(fast[name]),
                                   np.asarray(naive[name]),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 4, 64),      # MHA
    (1, 128, 128, 8, 2, 64),      # GQA 4x
    (2, 64, 128, 4, 1, 32),       # MQA, cross lengths
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(B, Sq, Sk, Hq, Hkv, D, causal,
                                       window, dtype):
    if not causal and Sq != Sk:
        pytest.skip("non-causal cross shape covered separately")
    from repro.kernels.flash_attention import flash_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out_p = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                   interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (4, 128, 4, 4, 64), (2, 256, 8, 2, 64), (3, 64, 4, 1, 32)])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_pallas_vs_ref(B, S, Hq, Hkv, D, window, dtype):
    from repro.kernels.decode_attention import decode_attention_pallas
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(2).integers(1, S + 1, B), jnp.int32)
    out_p = decode_attention_pallas(q, kc, vc, lengths, window=window,
                                    interpret=True)
    out_r = ref.decode_attention_ref(q, kc, vc, lengths, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (2, 128, 128, 4, 2, 32), (1, 64, 256, 8, 2, 32)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
@pytest.mark.parametrize("unroll", [False, True])
def test_flash_attention_xla_streaming_vs_ref(B, Sq, Sk, Hq, Hkv, D,
                                              causal, window, unroll):
    """The streaming online-softmax (dry-run lowering of the flash kernel)
    must equal the dense reference."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32)
    out_s = ref.flash_attention_xla(q, k, v, causal=causal, window=window,
                                    block_k=64, unroll=unroll)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,window,steps", [(16, 8, 20), (8, 8, 12)])
def test_decode_attention_ring_vs_prefix(S, window, steps):
    """Ring-layout decode == prefix-layout decode on the same history."""
    B, Hq, Hkv, D = 2, 4, 2, 16
    rng = jax.random.PRNGKey(9)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    hist_k = jax.random.normal(ks[1], (B, steps, Hkv, D), jnp.float32)
    hist_v = jax.random.normal(ks[2], (B, steps, Hkv, D), jnp.float32)
    pos = steps - 1
    # prefix layout: last `window` live entries, aligned at [0, steps)
    kp = jnp.pad(hist_k, ((0, 0), (0, max(0, S - steps)), (0, 0), (0, 0)))[:, :max(S, steps)]
    vp = jnp.pad(hist_v, ((0, 0), (0, max(0, S - steps)), (0, 0), (0, 0)))[:, :max(S, steps)]
    lengths = jnp.full((B,), steps, jnp.int32)
    want = ref.decode_attention_ref(q, kp[:, :steps], vp[:, :steps],
                                    lengths, window=window)
    # ring layout: entry for position t at slot t % S
    kr = jnp.zeros((B, S, Hkv, D), jnp.float32)
    vr = jnp.zeros((B, S, Hkv, D), jnp.float32)
    for t in range(steps):
        kr = kr.at[:, t % S].set(hist_k[:, t])
        vr = vr.at[:, t % S].set(hist_v[:, t])
    got = ref.decode_attention_ref(q, kr, vr,
                                   jnp.full((B,), pos, jnp.int32),
                                   window=window, ring=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # pallas kernel agrees in ring mode too
    from repro.kernels.decode_attention import decode_attention_pallas
    got_p = decode_attention_pallas(q, kr, vr,
                                    jnp.full((B,), pos, jnp.int32),
                                    window=window, ring=True,
                                    interpret=True, block_k=8)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_flash_last_row():
    """Decoding token t must equal row t of full flash attention."""
    B, S, H, D = 2, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q_full = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    full = ref.flash_attention_ref(q_full, k, v, causal=True)
    lengths = jnp.full((B,), S, jnp.int32)
    dec = ref.decode_attention_ref(q_full[:, -1], k, v, lengths)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# last_join (relational tier, DESIGN.md §8)
# ---------------------------------------------------------------------------

def _join_table(seed=7, capacity=64):
    """Right table with keys 0..5 populated (6, 7 empty), duplicate
    timestamps for tie coverage, and enough events to wrap the ring."""
    from repro.featurestore.table import Table, TableSchema
    rng = np.random.default_rng(seed)
    schema = TableSchema("right", key_col="k", ts_col="ts",
                         value_cols=("a", "b", "c"))
    t = Table(schema, max_keys=8, capacity=capacity, bucket_size=8)
    keys = rng.integers(0, 6, 500)
    ts = rng.uniform(1.0, 1000, 500)
    ts[50:60] = ts[50]                       # ties within one timestamp
    ts = np.sort(ts).astype(np.float32)
    rows = rng.normal(0, 2, (500, 3)).astype(np.float32)
    # prime keys 0..5 in order so key VALUE == dense index (the kernel
    # probes dense indices; the brute oracle filters by value)
    keys = np.concatenate([np.arange(6), keys])
    ts = np.concatenate([np.zeros(6, np.float32), ts])
    rows = np.concatenate([np.zeros((6, 3), np.float32), rows])
    t.insert(keys.tolist(), ts.tolist(), rows)
    assert all(t.key_to_idx[v] == v for v in range(6))
    return t, (keys, ts, rows)


def _brute_last_join(keys, ts, rows, rk, rt, col, capacity,
                     assume_latest=False):
    """Host oracle: latest RETAINED row of key rk with ts <= rt."""
    idx = np.where(keys == rk)[0][-capacity:]          # ring retention
    if assume_latest:
        sel = idx
    else:
        sel = idx[ts[idx] <= rt]
    if len(sel) == 0:
        return 0.0, False
    return float(rows[sel[-1], col]), True


@pytest.mark.parametrize("assume_latest", [False, True])
@pytest.mark.parametrize("col_idx", [(0,), (2, 0)])
def test_last_join_pallas_vs_ref_vs_brute(assume_latest, col_idx):
    from repro.kernels.last_join import last_join_pallas
    t, (keys, ts, rows) = _join_table()
    st = t.state
    rng = np.random.default_rng(5)
    # empty-key (6), pre-history (rt < first event), stale (rt far past
    # the last event), and ordinary mid-history requests
    req_key = jnp.asarray(
        list(rng.integers(0, 6, 12)) + [6, 0, 1, 2], jnp.int32)
    req_ts = jnp.asarray(
        list(np.sort(rng.uniform(100, 900, 12)))
        + [500.0, -5.0, 1e6, float(ts[55])], jnp.float32)
    kw = dict(col_idx=col_idx, assume_latest=assume_latest)
    row_p, m_p = last_join_pallas(st.values, st.ts, st.total, req_key,
                                  req_ts, interpret=True, **kw)
    row_r, m_r = ref.last_join_ref(st.values, st.ts, st.total, req_key,
                                   req_ts, **kw)
    np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_r))
    np.testing.assert_allclose(np.asarray(row_p), np.asarray(row_r),
                               rtol=1e-6, atol=1e-6)
    for i in range(len(req_key)):
        for oi, ci in enumerate(col_idx):
            want, matched = _brute_last_join(
                keys, ts, rows, int(req_key[i]), float(req_ts[i]), ci,
                t.capacity, assume_latest=assume_latest)
            assert bool(m_r[i]) == matched, i
            got = float(row_r[i, oi]) if matched else None
            if matched:
                assert got == pytest.approx(want, abs=1e-5), (i, ci)
            else:
                assert float(row_r[i, oi]) == 0.0, (i, ci)


@pytest.mark.parametrize("assume_latest", [False, True])
def test_last_join_with_ts_parity_and_age_semantics(assume_latest):
    """``with_ts=True`` (staleness-metrics input): pallas and ref agree
    on the selected row's timestamp, which equals the brute-force latest
    qualifying ts (zero when unmatched)."""
    from repro.kernels.last_join import last_join_pallas
    t, (keys, ts, rows) = _join_table()
    st = t.state
    rng = np.random.default_rng(13)
    req_key = jnp.asarray(list(rng.integers(0, 6, 10)) + [6], jnp.int32)
    req_ts = jnp.asarray(
        list(np.sort(rng.uniform(100, 900, 10))) + [500.0], jnp.float32)
    kw = dict(col_idx=(0, 1), assume_latest=assume_latest, with_ts=True)
    row_p, m_p, ts_p = last_join_pallas(st.values, st.ts, st.total,
                                        req_key, req_ts, interpret=True,
                                        **kw)
    row_r, m_r, ts_r = ref.last_join_ref(st.values, st.ts, st.total,
                                         req_key, req_ts, **kw)
    np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_r))
    np.testing.assert_allclose(np.asarray(ts_p), np.asarray(ts_r),
                               rtol=1e-6, atol=1e-6)
    cap = t.capacity
    for i in range(len(req_key)):
        k, rt_i = int(req_key[i]), float(req_ts[i])
        idx = np.where(keys == k)[0][-cap:]
        sel = idx if assume_latest else idx[ts[idx] <= rt_i]
        if len(sel):
            assert bool(m_r[i])
            assert float(ts_r[i]) == pytest.approx(float(ts[sel[-1]]),
                                                   abs=1e-5)
            # the engine's derived age is non-negative for real requests
            if not assume_latest:
                assert rt_i - float(ts_r[i]) >= -1e-5
        else:
            assert not bool(m_r[i]) and float(ts_r[i]) == 0.0


def test_last_join_empty_table_and_single_row():
    """Degenerate rings: an entirely empty right table never matches; a
    single-row table matches exactly when its one ts qualifies."""
    from repro.featurestore.table import Table, TableSchema
    from repro.kernels.last_join import last_join_pallas
    schema = TableSchema("right", key_col="k", ts_col="ts",
                         value_cols=("a",))
    t = Table(schema, max_keys=4, capacity=16, bucket_size=4)
    st = t.state
    rk = jnp.asarray([0, 1, 2], jnp.int32)
    rt = jnp.asarray([10.0, 0.0, 1e9], jnp.float32)
    for fn in (ref.last_join_ref,
               lambda *a, **k: last_join_pallas(*a, interpret=True, **k)):
        row, m = fn(st.values, st.ts, st.total, rk, rt, col_idx=(0,))
        assert not np.any(np.asarray(m))
        np.testing.assert_array_equal(np.asarray(row), 0.0)
    t.insert([0], [100.0], np.asarray([[7.5]], np.float32))
    st = t.state
    rt = jnp.asarray([99.0, 100.0, 101.0], jnp.float32)
    rk = jnp.asarray([0, 0, 0], jnp.int32)
    for fn in (ref.last_join_ref,
               lambda *a, **k: last_join_pallas(*a, interpret=True, **k)):
        row, m = fn(st.values, st.ts, st.total, rk, rt, col_idx=(0,))
        assert list(np.asarray(m)) == [False, True, True]
        np.testing.assert_allclose(np.asarray(row[:, 0]), [0.0, 7.5, 7.5])


def test_last_join_requires_columns():
    t, _ = _join_table()
    st = t.state
    with pytest.raises(ValueError, match="at least one value column"):
        ref.last_join_ref(st.values, st.ts, st.total,
                          jnp.asarray([0], jnp.int32),
                          jnp.asarray([1.0], jnp.float32), col_idx=())
