"""Synthetic data generator + host pipeline (prefetch, ordering, hedging)."""
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import HostPipeline, PipelineConfig
from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  make_labels, request_stream,
                                  token_batch_stream)


def test_generator_deterministic():
    cfg = EventStreamConfig(n_events=500, seed=7)
    a = generate_events(cfg)
    b = generate_events(cfg)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_generator_properties():
    cfg = EventStreamConfig(n_events=5000, n_keys=64, zipf_alpha=1.3)
    keys, ts, rows = generate_events(cfg)
    assert np.all(np.diff(ts) >= 0)                 # time-ordered
    assert rows.shape == (5000, cfg.n_features)
    assert np.all(rows[:, 0] > 0)                   # lognormal amounts
    # zipf: the most popular key dominates the median one
    _, freq = np.unique(keys, return_counts=True)
    assert freq.max() > 5 * np.median(freq)


def test_labels_plantable():
    cfg = EventStreamConfig(n_events=3000, n_keys=32)
    keys, ts, rows = generate_events(cfg)
    y = make_labels(keys, ts, rows)
    assert y.shape == (3000,)
    assert 0.0 < y.mean() < 0.5                     # rare positives


def test_request_stream_horizon():
    cfg = EventStreamConfig(n_events=200)
    keys, ts, rows = generate_events(cfg)
    for ks, rts in request_stream(keys, ts, batch=16, n_batches=3):
        assert len(ks) == 16
        assert np.all(rts > ts.max())               # online "now" queries


def test_token_stream_shapes():
    it = token_batch_stream(vocab=100, batch=4, seq=16, n_batches=2)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    assert b["targets"].shape == (4, 16)
    assert b["tokens"].max() < 100


def test_pipeline_in_order_delivery():
    def producer(i):
        time.sleep(0.001 * ((i * 7) % 5))           # jittered producers
        return i

    p = HostPipeline(producer, n_batches=20,
                     cfg=PipelineConfig(prefetch=4, n_workers=3))
    got = list(p)
    assert got == list(range(20))


def test_pipeline_propagates_errors():
    def producer(i):
        if i == 3:
            raise RuntimeError("producer died")
        return i

    p = HostPipeline(producer, n_batches=10)
    with pytest.raises(RuntimeError, match="producer died"):
        list(p)


def test_pipeline_hedging_beats_straggler():
    calls = []

    def producer(i):
        calls.append(i)
        if i == 2 and calls.count(2) == 1:
            time.sleep(0.4)                         # first attempt straggles
        return i

    p = HostPipeline(producer, n_batches=6,
                     cfg=PipelineConfig(prefetch=2, n_workers=2,
                                        hedge_after_s=0.05, max_hedges=1))
    t0 = time.perf_counter()
    got = list(p)
    dt = time.perf_counter() - t0
    assert got == list(range(6))
    assert p.stats["hedges"] >= 1
    assert dt < 0.4                                 # hedge avoided the stall
