"""End-to-end training driver: loss goes down, checkpoint/restart works,
the NaN supervisor rolls back, resume is exact."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.launch.train import TrainLoop, make_batches
from repro.optim.adamw import AdamWConfig


def make_loop(tmp_path=None, arch="qwen1.5-0.5b", steps=12, lr=1e-3):
    cfg = reduced(get_config(arch))
    opt = AdamWConfig(lr=lr, warmup_steps=2, total_steps=steps)
    return cfg, TrainLoop(cfg, opt_cfg=opt,
                          ckpt_dir=str(tmp_path) if tmp_path else None,
                          retain=2)


def test_loss_decreases():
    cfg, loop = make_loop(steps=12)
    batches = make_batches(cfg, batch=4, seq=32, seed=0, pipeline=False)
    out = loop.run(batches, steps=12, log_every=0)
    h = out["history"]
    first = np.mean([m["loss"] for m in h[:3]])
    last = np.mean([m["loss"] for m in h[-3:]])
    assert np.isfinite(last)
    assert last < first, (first, last)


def test_checkpoint_resume_exact(tmp_path):
    cfg, loop = make_loop(tmp_path, steps=8)
    batches = list(
        b for b, _ in zip(make_batches(cfg, batch=4, seq=16, seed=0,
                                       pipeline=False), range(16)))
    loop.run(iter(batches), steps=8, ckpt_every=4, log_every=0)
    loop.save(block=True)
    w_end = np.asarray(jax.tree_util.tree_leaves(loop.params)[0])

    # new loop, same config: restore and compare
    cfg2, loop2 = make_loop(tmp_path, steps=8)
    assert loop2.restore()
    assert loop2.step == 8
    w_res = np.asarray(jax.tree_util.tree_leaves(loop2.params)[0])
    np.testing.assert_array_equal(w_end, w_res)


def test_supervisor_rolls_back_on_nan(tmp_path):
    cfg, loop = make_loop(tmp_path, steps=20)
    good = list(b for b, _ in zip(
        make_batches(cfg, batch=4, seq=16, seed=0, pipeline=False),
        range(4)))
    loop.run(iter(good), steps=2, ckpt_every=2, log_every=0)
    loop.ckpt.wait()
    assert loop.ckpt.latest_step() == 2

    # poison the params to force non-finite steps
    loop.params = jax.tree_util.tree_map(lambda a: a * jnp.nan, loop.params)

    def poisoned_stream():
        while True:
            yield good[0]

    out = loop.run(poisoned_stream(), steps=6, ckpt_every=0,
                   max_bad_steps=2, log_every=0)
    # rollback happened: params are finite again (restored from step 2)
    leaf = np.asarray(jax.tree_util.tree_leaves(loop.params)[0])
    assert np.isfinite(leaf).all()
    assert any(m.get("rolled_back") for m in out["history"])


def test_driver_cli_smoke(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "4",
               "--batch", "2", "--seq", "16", "--ckpt-dir",
               str(tmp_path), "--ckpt-every", "2"])
    assert rc == 0
    assert os.listdir(str(tmp_path))


def test_serve_cli_smoke(tmp_path):
    from repro.launch.serve import main
    out = os.path.join(str(tmp_path), "m.json")
    rc = main(["--requests", "128", "--batch", "32", "--events", "2000",
               "--keys", "32", "--metrics-out", out])
    assert rc == 0
    import json
    with open(out) as f:
        rep = json.load(f)
    assert rep["qps"] > 0
    assert rep["n_features"] == 10
