"""Relational tier: point-in-time LAST JOIN across tables (DESIGN.md §8).

Covers the acceptance surface of the multi-table tier: online/offline
joined-feature parity on a disordered streamed load, empty/missing-key/
stale-row semantics, catalog-backed validation errors, join-aware column
pruning + probe ordering, EXPLAIN's join section, per-join kernel-launch
accounting, and the host-dict keydir fallback.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dsl
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema

JOIN_SQL = """
SELECT SUM(amount) OVER w AS s,
       merchants.rating AS rating,
       risk AS risk
FROM events
LAST JOIN merchants ORDER BY mts ON merchant
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)
"""


def make_join_engine(flags=OptFlags(), n_events=400, n_merchants=6,
                     seed=0, merchant_snaps=(100.0, 400.0, 800.0)):
    """events(amount, merchant) LAST JOIN merchants(rating, risk).

    Merchant profiles are re-published at each timestamp in
    ``merchant_snaps`` so point-in-time requests see different versions.
    """
    eng = Engine(flags)
    eng.create_table(TableSchema("events", key_col="user", ts_col="ts",
                                 value_cols=("amount", "merchant")),
                     max_keys=32, capacity=512, bucket_size=32)
    eng.create_table(TableSchema("merchants", key_col="merchant",
                                 ts_col="mts",
                                 value_cols=("rating", "risk")),
                     max_keys=16, capacity=64, bucket_size=8)
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 8, n_events)
    ts = np.sort(rng.uniform(0, 1000, n_events)).astype(np.float32)
    mids = rng.integers(0, n_merchants, n_events)
    rows = np.stack([rng.normal(0, 2, n_events),
                     mids.astype(np.float64)], -1).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)

    mkeys, mts, mrows = [], [], []
    for t0 in merchant_snaps:
        for m in range(n_merchants):
            mkeys.append(m)
            mts.append(t0 + m * 0.01)
            mrows.append([m + t0 / 1000.0, m * 0.1 + t0])
    order = np.argsort(mts, kind="stable")
    eng.insert("merchants", [mkeys[i] for i in order],
               [mts[i] for i in order],
               np.asarray(mrows, np.float32)[order])
    mdata = (np.asarray(mkeys)[order],
             np.asarray(mts, np.float32)[order],
             np.asarray(mrows, np.float32)[order])
    return eng, (keys, ts, rows), mdata


def brute_join(mdata, probe, req_ts, col):
    """Latest merchant row with mts <= req_ts; 0.0 when none."""
    mkeys, mts, mrows = mdata
    out = []
    for k, t in zip(probe, req_ts):
        m = (mkeys == k) & (mts <= t)
        out.append(mrows[np.where(m)[0][-1], col] if m.any() else 0.0)
    return np.asarray(out, np.float32)


def test_last_join_enriches_online_requests():
    eng, (keys, ts, rows), mdata = make_join_engine()
    eng.deploy("f", JOIN_SQL)
    rk, rt, rr = keys[:8].tolist(), (ts[:8] + 2000).tolist(), rows[:8]
    out = eng.request("f", rk, rt, rows=rr)
    np.testing.assert_allclose(
        out["rating"], brute_join(mdata, rr[:, 1], rt, 0), rtol=1e-5)
    np.testing.assert_allclose(
        out["risk"], brute_join(mdata, rr[:, 1], rt, 1), rtol=1e-5)
    eng.close()


def test_join_staleness_metrics_match_rate_and_age_percentiles():
    """Right-table ring staleness observability (ROADMAP item): per-
    deployment join match-rate + right-row age percentiles, surfaced in
    EXPLAIN and latency_decomposition; offline runs don't pollute it."""
    eng, (keys, ts, rows), mdata = make_join_engine()
    eng.deploy("f", JOIN_SQL)
    dep = eng.handle("f")
    rk = keys[:8].tolist()
    rt = np.full(8, 2000.0, np.float32).tolist()
    rr = rows[:8].copy()
    out = eng.request("f", rk, rt, rows=rr)
    assert "__join_match_merchants" not in out.columns   # hidden, stripped
    st = dep.join_staleness()["merchants"]
    assert st["probes"] == 8 and st["matches"] == 8
    assert st["match_rate"] == 1.0
    # newest merchant re-publish is at ~800, requests at 2000 -> ages in
    # [2000-800.06, 2000-800] give or take the per-merchant 0.01 stagger
    assert 1150.0 < st["age_p50"] < 1250.0
    assert st["age_p50"] <= st["age_p99"] < 1250.0
    assert st["age_samples"] == 8

    # unknown probe keys count as unmatched probes (match rate drops)
    rr_bad = rr.copy()
    rr_bad[:, 1] = 99.0
    eng.request("f", rk, rt, rows=rr_bad)
    st2 = dep.join_staleness()["merchants"]
    assert st2["probes"] == 16 and st2["matches"] == 8
    assert st2["match_rate"] == 0.5
    assert st2["age_samples"] == 8               # no ages for misses

    # surfaced in EXPLAIN + engine-level latency decomposition
    txt = eng.explain("f")
    assert "staleness" in txt and "match_rate=0.500" in txt
    dec = eng.latency_decomposition()
    assert dec["join_probes"] == 16
    assert abs(dec["join_match_rate"] - 0.5) < 1e-9
    assert 1150.0 < dec["join_age_p99"] < 1250.0

    # offline materialisation must not skew serving staleness
    eng.query_offline("f")
    st3 = dep.join_staleness()["merchants"]
    assert st3["probes"] == 16
    eng.close()


def test_builder_tcol_equivalent_to_sql():
    eng, (keys, ts, rows), _ = make_join_engine()
    eng.deploy("sql", JOIN_SQL)
    m = dsl.tbl("merchants")
    qb = (dsl.QueryBuilder("events")
          .window("w", partition_by="user", order_by="ts", rows=20)
          .last_join("merchants", on="merchant", order_by="mts")
          .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                  rating=m.rating, risk=m["risk"]))
    eng.deploy("py", qb)
    rk, rt, rr = keys[:6].tolist(), (ts[:6] + 2000).tolist(), rows[:6]
    a = eng.request("sql", rk, rt, rows=rr)
    b = eng.request("py", rk, rt, rows=rr)
    for name in a.keys():
        np.testing.assert_array_equal(np.asarray(a[name]),
                                      np.asarray(b[name]), err_msg=name)
    eng.close()


def test_point_in_time_parity_online_offline_disordered_stream():
    """The acceptance property: handle.request and query_offline produce
    BIT-IDENTICAL joined features for every stored event, with the events
    arriving as a disordered stream (repaired by the watermark buffer)."""
    eng = Engine(OptFlags(assume_latest=False))
    eng.create_table(TableSchema("events", key_col="user", ts_col="ts",
                                 value_cols=("amount", "merchant")),
                     max_keys=16, capacity=512, bucket_size=32)
    eng.create_table(TableSchema("merchants", key_col="merchant",
                                 ts_col="mts", value_cols=("rating",)),
                     max_keys=8, capacity=64, bucket_size=8)
    eng.attach_stream("events", lateness=50.0)
    rng = np.random.default_rng(4)
    N = 300
    keys = rng.integers(0, 6, N)
    ts = np.sort(rng.uniform(0, 500, N)).astype(np.float32)
    rows = np.stack([rng.normal(0, 2, N),
                     rng.integers(0, 4, N).astype(np.float64)],
                    -1).astype(np.float32)
    # disordered delivery: shuffle within lateness-sized chunks; the
    # reorder buffer repairs it before publication
    order = np.arange(N)
    for s in range(0, N, 40):
        rng.shuffle(order[s:s + 40])
    pipe = eng.streams["events"]
    for i in order:
        assert pipe.push(int(keys[i]), float(ts[i]), rows[i])
    pipe.flush()
    for t0 in (50.0, 250.0):
        eng.insert("merchants", [0, 1, 2, 3],
                   [t0, t0, t0, t0],
                   np.asarray([[m + t0] for m in range(4)], np.float32))

    eng.deploy("f", """
        SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c,
               merchants.rating AS rating
        FROM events LAST JOIN merchants ORDER BY mts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)""")
    off = eng.query_offline("f")
    assert len(off["s"]) == N
    # online replay of every stored event at its own timestamp
    h = eng.handle("f")
    on = h.request(keys.tolist(), ts.tolist(), rows=rows)
    assert on.all_ok
    k2i = eng.tables["events"].key_to_idx
    pos = {}
    for i, (k, t) in enumerate(zip(off["__key"], off["__ts"])):
        pos.setdefault((int(k), np.float32(t)), []).append(i)
    for j in range(N):
        cand = pos[(k2i[int(keys[j])], np.float32(ts[j]))]
        matches = [i for i in cand
                   if all(np.asarray(off[n][i]) == np.asarray(on[n][j])
                          for n in ("s", "c", "rating"))]
        assert matches, (j, [(off["s"][i], on["s"][j]) for i in cand])
    eng.close()


def test_missing_key_empty_table_and_stale_rows():
    eng, (keys, ts, rows), mdata = make_join_engine()
    # a third, EMPTY right table joined in the same query
    eng.create_table(TableSchema("devices", key_col="merchant",
                                 ts_col="dts", value_cols=("trust",)),
                     max_keys=8, capacity=16, bucket_size=4)
    eng.deploy("f", """
        SELECT SUM(amount) OVER w AS s, merchants.rating AS rating,
               devices.trust AS trust
        FROM events
        LAST JOIN merchants ORDER BY mts ON merchant
        LAST JOIN devices ORDER BY dts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    rk = keys[:4].tolist()
    rt = (ts[:4] + 5000).tolist()          # stale: far past last update
    rr = rows[:4].copy()
    rr[0, 1] = 999.0                       # missing right key
    out = eng.request("f", rk, rt, rows=rr)
    assert out.all_ok                      # main keys are known
    assert out["rating"][0] == 0.0         # missing key -> masked zero
    np.testing.assert_allclose(            # stale rows still join (latest)
        out["rating"][1:], brute_join(mdata, rr[1:, 1], rt[1:], 0),
        rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(out["trust"]), 0.0)  # empty
    eng.close()


def test_point_in_time_before_first_right_row_is_unmatched():
    eng, (keys, ts, rows), mdata = make_join_engine(
        OptFlags(assume_latest=False))
    eng.deploy("f", JOIN_SQL)
    idx = np.where(ts < 99.0)[0][:4]       # before the first profile snap
    out = eng.request("f", keys[idx].tolist(), ts[idx].tolist(),
                      rows=rows[idx])
    np.testing.assert_array_equal(np.asarray(out["rating"]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["risk"]), 0.0)
    eng.close()


def test_predict_over_joined_features():
    eng, (keys, ts, rows), mdata = make_join_engine()

    def scorer(params, feats):
        return jnp.asarray(feats) @ jnp.asarray(params)

    eng.register_model("scorer", scorer, np.asarray([2.0, 0.5], np.float32))
    eng.deploy("ml", """
        SELECT SUM(amount) OVER w AS s,
               PREDICT(scorer, s, merchants.risk) AS score
        FROM events LAST JOIN merchants ORDER BY mts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    rk, rt, rr = keys[:5].tolist(), (ts[:5] + 2000).tolist(), rows[:5]
    got = eng.request("ml", rk, rt, rows=rr)
    want = (np.asarray(got["s"]) * 2.0
            + 0.5 * brute_join(mdata, rr[:, 1], rt, 1))
    np.testing.assert_allclose(got["score"], want, rtol=1e-4, atol=1e-4)
    eng.close()


def test_join_launch_accounting():
    """Exactly one extra kernel launch per joined table, observed both in
    the plan counter and the engine's cumulative launch stats."""
    eng, (keys, ts, rows), _ = make_join_engine()
    eng.create_table(TableSchema("devices", key_col="merchant",
                                 ts_col="dts", value_cols=("trust",)),
                     max_keys=8, capacity=16, bucket_size=4)
    eng.insert("devices", [0, 1], [1.0, 1.0],
               np.ones((2, 1), np.float32))
    base = eng.deploy("plain", """
        SELECT SUM(amount) OVER w AS s, amount AS amount
        FROM events
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    joined = eng.deploy("j2", """
        SELECT SUM(amount) OVER w AS s, merchants.rating AS rating,
               devices.trust AS trust
        FROM events
        LAST JOIN merchants ORDER BY mts ON merchant
        LAST JOIN devices ORDER BY dts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    assert joined.phys.n_kernel_launches == base.phys.n_kernel_launches + 2
    before = eng.stats.kernel_launches
    eng.request("j2", keys[:4].tolist(), (ts[:4] + 2000).tolist(),
                rows=rows[:4])
    assert (eng.stats.kernel_launches - before
            == joined.phys.n_kernel_launches)
    eng.close()


def test_join_pruning_ordering_and_explain_shape():
    """EXPLAIN prints the join section: probe order, per-join keydir,
    pruned right-table columns; the optimizer orders probes by cost and
    drops unused joins."""
    eng, (keys, ts, rows), _ = make_join_engine()
    # wide right table, cheap to probe only when pruned
    eng.create_table(TableSchema("devices", key_col="merchant",
                                 ts_col="dts",
                                 value_cols=("trust", "age", "score")),
                     max_keys=8, capacity=16, bucket_size=4)
    eng.insert("devices", [0, 1], [1.0, 1.0],
               np.full((2, 3), 2.0, np.float32))
    dep = eng.deploy("f", """
        SELECT SUM(amount) OVER w AS s, devices.trust AS trust,
               merchants.rating AS rating
        FROM events
        LAST JOIN merchants ORDER BY mts ON merchant
        LAST JOIN devices ORDER BY dts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    # pruning: devices carries only 'trust'; ordering: devices (C=16,
    # 1 col) probes before merchants (C=64, 1 col)
    jmap = {j.table: j for j in dep.plan.joins}
    assert jmap["devices"].columns == ("trust",)
    assert [j.table for j in dep.plan.joins] == ["devices", "merchants"]
    assert any("join_prune" in l for l in dep.opt_log)
    assert any("join_order" in l for l in dep.opt_log)

    txt = eng.explain("f")
    lines = txt.splitlines()
    order_lines = [l for l in lines if "join probe order:" in l]
    assert len(order_lines) == 1
    assert "devices -> merchants" in order_lines[0]
    jlines = [l.strip() for l in lines if l.strip().startswith("join ")
              and "LAST JOIN" in l]
    assert len(jlines) == 2
    for l in jlines:
        assert "on=merchant" in l and "keydir=" in l and "pruned=" in l
    assert ("join devices: LAST JOIN on=merchant order_by=dts "
            "cols=['trust'] pruned=['age', 'score'] "
            "keydir=device-keydir" in txt)
    # a join nothing references is dropped from the plan entirely
    dep2 = eng.deploy("g", """
        SELECT SUM(amount) OVER w AS s
        FROM events LAST JOIN merchants ORDER BY mts ON merchant
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)""")
    assert dep2.plan.joins == ()
    assert any("dropped unused join" in l for l in dep2.opt_log)
    eng.close()


def test_keydir_fallback_matches_device_probe():
    eng, (keys, ts, rows), _ = make_join_engine()
    eng.deploy("f", JOIN_SQL)
    rk, rt = keys[:6].tolist(), (ts[:6] + 2000).tolist()
    rr = rows[:6].copy()
    rr[2, 1] = 777.0                        # one unknown probe key
    fast = eng.request("f", rk, rt, rows=rr)
    eng.tables["merchants"].keydir.active = False
    slow = eng.request("f", rk, rt, rows=rr)
    for n in fast.keys():
        np.testing.assert_array_equal(np.asarray(fast[n]),
                                      np.asarray(slow[n]), err_msg=n)
    assert "keydir=host-dict(fallback)" in eng.explain("f")
    eng.close()


def test_joined_deployment_requires_request_rows():
    """rows=None would zero-fill the probe column and silently join
    right-table key 0 for every request — must be rejected instead."""
    eng, (keys, ts, rows), _ = make_join_engine()
    eng.deploy("f", JOIN_SQL)
    with pytest.raises(ValueError, match="must pass rows="):
        eng.request("f", keys[:2].tolist(), (ts[:2] + 2000).tolist())
    eng.close()


def test_non_integral_probe_values_never_match():
    eng, (keys, ts, rows), _ = make_join_engine()
    eng.deploy("f", JOIN_SQL)
    rr = rows[:2].copy()
    rr[:, 1] = [0.5, 2.25]                 # not representable as keys
    out = eng.request("f", keys[:2].tolist(), (ts[:2] + 2000).tolist(),
                      rows=rr)
    np.testing.assert_array_equal(np.asarray(out["rating"]), 0.0)
    eng.close()


# ---------------------------------------------------------------------------
# validation errors (satellite: clear, actionable messages)
# ---------------------------------------------------------------------------

def _deploy_err(eng, q, match):
    with pytest.raises(ValueError, match=match):
        eng.deploy("bad", q)


def test_validation_error_messages():
    eng, *_ = make_join_engine()
    W = """ WINDOW w AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)"""
    # last_join without order_by: its own actionable message
    _deploy_err(eng, (dsl.QueryBuilder("events")
                      .window("w", partition_by="user", order_by="ts",
                              rows=5)
                      .last_join("merchants", on="merchant")
                      .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                              r=dsl.tbl("merchants").rating)),
                "requires order_by")
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, merchants.rating AS r"
                     " FROM events LAST JOIN merchants ON merchant" + W,
                "requires order_by")
    # order_by must be the right table's ts column
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, merchants.rating AS r"
                     " FROM events LAST JOIN merchants ORDER BY rating "
                     "ON merchant" + W,
                "timestamp column 'mts'")
    # unknown right table / undeclared join key / missing left column
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, nope.x AS r"
                     " FROM events LAST JOIN nope ORDER BY ts ON merchant"
                     + W, "unknown table 'nope'")
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, merchants.rating AS r"
                     " FROM events LAST JOIN merchants ORDER BY mts "
                     "ON rating" + W,
                "not a declared join key")
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, merchants.rating AS r"
                     " FROM events LAST JOIN merchants ORDER BY mts "
                     "ON merchant_id" + W,
                "not a declared join key")
    eng.close()


def test_window_over_joined_columns_rejected():
    eng, *_ = make_join_engine()
    base = ("SELECT SUM(amount) OVER w AS s, merchants.rating AS r "
            "FROM events LAST JOIN merchants ORDER BY mts ON merchant ")
    # qualified partition_by: caught structurally (no catalog needed)
    _deploy_err(eng, base + "WINDOW w AS (PARTITION BY merchants.rating "
                "ORDER BY ts ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)",
                r"windows index the main table's \(key, ts\) only")
    # unqualified right-only order_by: caught by catalog resolution
    _deploy_err(eng, base + "WINDOW w AS (PARTITION BY user ORDER BY "
                "risk ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)",
                r"windows index the main table's \(key, ts\) only")
    # window aggregate over a joined column: the scan never sees it
    _deploy_err(eng, "SELECT SUM(merchants.risk) OVER w AS s "
                "FROM events LAST JOIN merchants ORDER BY mts ON merchant "
                "WINDOW w AS (PARTITION BY user ORDER BY ts "
                "ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)",
                "window aggregate")
    # WHERE over a joined column: filters run on raw events pre-join
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, "
                "merchants.rating AS r FROM events "
                "LAST JOIN merchants ORDER BY mts ON merchant "
                "WHERE risk > 0 "
                "WINDOW w AS (PARTITION BY user ORDER BY ts "
                "ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)",
                "WHERE")
    eng.close()


def test_ambiguous_and_duplicate_joins_rejected():
    eng, *_ = make_join_engine()
    # second right table sharing the 'rating' column name
    eng.create_table(TableSchema("shops", key_col="merchant",
                                 ts_col="sts", value_cols=("rating",)),
                     max_keys=8, capacity=16, bucket_size=4)
    W = """ WINDOW w AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)"""
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, rating AS r"
                     " FROM events"
                     " LAST JOIN merchants ORDER BY mts ON merchant"
                     " LAST JOIN shops ORDER BY sts ON merchant" + W,
                "ambiguous")
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, merchants.rating AS r"
                     " FROM events"
                     " LAST JOIN merchants ORDER BY mts ON merchant"
                     " LAST JOIN merchants ORDER BY mts ON merchant" + W,
                "JOINed twice")
    _deploy_err(eng, "SELECT SUM(amount) OVER w AS s, events.amount AS r"
                     " FROM events"
                     " LAST JOIN events ORDER BY ts ON merchant" + W,
                "itself")
    eng.close()


def test_qualified_column_without_join_rejected():
    eng, *_ = make_join_engine()
    _deploy_err(eng, """
        SELECT SUM(amount) OVER w AS s, merchants.rating AS r FROM events
        WINDOW w AS (PARTITION BY user ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""",
                "not LAST JOINed")
    eng.close()


def test_catalog_rejects_secondary_join_keys():
    eng = Engine(OptFlags())
    with pytest.raises(ValueError, match="multi-key indexes"):
        eng.create_table(TableSchema("t", key_col="k", ts_col="ts",
                                     value_cols=("a", "b")),
                         join_keys=("a",))
    eng.close()


def test_optimize_without_catalog_rejects_joins():
    from repro.core.optimizer import TableMeta, optimize
    q = (dsl.QueryBuilder("events")
         .window("w", partition_by="user", order_by="ts", rows=5)
         .last_join("merchants", on="merchant", order_by="mts")
         .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                 r=dsl.tbl("merchants").rating)).build()
    meta = TableMeta(capacity=64, bucket_size=8, n_value_cols=2,
                     has_preagg=False)
    with pytest.raises(ValueError, match="no relational catalog"):
        optimize(q.to_logical(), meta, OptFlags())
