"""Training-serving consistency — the paper's core promise (§3.3): one SQL
feature definition, identical values online (request path) and offline
(batch materialisation path)."""
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema

SQL = """
SELECT SUM(amount) OVER w AS s,
       AVG(amount) OVER w AS a,
       COUNT(amount) OVER w AS c,
       MAX(amount) OVER w AS mx
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)
"""


def build(flags=OptFlags()):
    eng = Engine(flags)
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount",))
    eng.create_table(schema, max_keys=32, capacity=128, bucket_size=16)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 8, 400)
    ts = np.sort(rng.uniform(0, 500, 400)).astype(np.float32)
    rows = rng.normal(0, 2, (400, 1)).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy("f", SQL)
    return eng, keys, ts, rows


def test_offline_materialisation_matches_online_requests():
    """query_offline computes point-in-time features for every stored
    event; re-requesting the same (key, ts) online (with assume_latest
    off) must give bit-identical results."""
    eng, keys, ts, rows = build(OptFlags(assume_latest=False))
    off = eng.query_offline("f")
    # online replay of the same (key, ts) pairs
    kidx = off["__key"]
    k_rev = {v: k for k, v in eng.tables["events"].key_to_idx.items()}
    req_keys = [k_rev[int(k)] for k in kidx]
    on = eng.request("f", req_keys, off["__ts"].tolist())
    for name in ("s", "a", "c", "mx"):
        np.testing.assert_allclose(off[name], on[name], rtol=1e-6,
                                   atol=1e-6, err_msg=name)


def test_offline_is_point_in_time():
    """No feature leakage: an event's offline features must not see any
    later event (the training-serving-skew guarantee)."""
    eng, keys, ts, rows = build()
    off = eng.query_offline("f")
    kidx = np.asarray(off["__key"])
    ots = np.asarray(off["__ts"])
    # brute-force point-in-time count for a sample of events
    table = eng.tables["events"]
    for i in range(0, len(kidx), 37):
        k = int(kidx[i])
        key_label = [kk for kk, vv in table.key_to_idx.items()
                     if vv == k][0]
        m = (keys == key_label) & (ts <= ots[i])
        # window = last 20 stored events with ts <= event ts (incl. itself),
        # clipped at the ring eviction horizon (capacity 128 per key)
        p1 = int(m.sum())
        total_k = int((keys == key_label).sum())
        p0 = max(p1 - 20, 0, total_k - 128)
        want = p1 - p0
        assert off["c"][i] == pytest.approx(want, abs=1e-5), i


def test_feature_registry_single_definition():
    """One FeatureSet powers both modes (unified definition, §3.3)."""
    eng, *_ = build()
    fs = eng.registry.get("f")
    assert fs is not None
    assert fs.query.table == "events"
    assert "events" in eng.registry.schemas
