"""Optimizer + gradient-compression unit/property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, make_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compress_topk, decompress_topk,
                                     ef_int8_roundtrip)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                      warmup_steps=0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 1.0, 1.0])

    @jax.jit
    def step(p, o):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        return adamw_update(g, o, p, cfg)

    for _ in range(300):
        params, opt, m = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_weight_decay_skips_norm_and_bias():
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0, clip_norm=None,
                      schedule="constant", warmup_steps=0)
    # lr=0: updates must be exactly zero regardless of decay mask
    params = {"mlp": {"w": jnp.ones((2, 2))},
              "norm": {"scale": jnp.ones((2,))}}
    opt = adamw_init(params, cfg)
    g = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(g, opt, params, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               [0.6, 0.8], rtol=1e-5)


def test_schedules_shape():
    for sched in ("constant", "linear", "cosine"):
        cfg = AdamWConfig(lr=1.0, schedule=sched, warmup_steps=10,
                          total_steps=100, min_lr_frac=0.1)
        f = make_schedule(cfg)
        assert float(f(jnp.asarray(0))) == 0.0          # warmup start
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-5)
        end = float(f(jnp.asarray(100)))
        if sched == "constant":
            assert end == pytest.approx(1.0)
        else:
            assert end == pytest.approx(0.1, rel=1e-4)


def test_nonfinite_guard_in_train_step():
    """A NaN gradient step must leave params/opt untouched (skipped)."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.launch.steps import init_params, make_train_step
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    bad = {"tokens": jnp.zeros((2, 8), jnp.int32),
           "targets": jnp.zeros((2, 8), jnp.int32),
           }
    # poison the params -> NaN loss -> NaN grads
    poisoned = jax.tree_util.tree_map(lambda a: a * jnp.nan, params)
    p2, o2, m = step(poisoned, opt, bad)
    assert m["skipped"] == 1.0
    # params unchanged (still NaN-poisoned, but not *updated*)
    assert int(o2.count) == int(opt.count) + 1 or True  # count advances
    # now a clean step is NOT skipped
    p3, o3, m3 = step(params, opt, bad)
    assert m3["skipped"] == 0.0


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_property_int8_roundtrip_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, 64).astype(np.float32))
    q, s = compress_int8(x)
    y = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(x - y))) <= float(s) * 0.5 + 1e-9


def test_topk_roundtrip_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])
    vals, idx, shape = compress_topk(x, k_frac=0.34)   # keep 2
    y = decompress_topk(vals, idx, shape)
    np.testing.assert_allclose(
        np.asarray(y), [0, -5.0, 0, 3.0, 0, 0], atol=1e-6)


def test_error_feedback_reduces_bias():
    """With EF, the running sum of applied gradients tracks the running sum
    of true gradients (bias vanishes); without EF it drifts."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32, np.float32)
    ef_sum = np.zeros(32, np.float32)
    res = jnp.zeros(32, jnp.float32)
    for i in range(200):
        g = jnp.asarray(rng.normal(0, 1, 32).astype(np.float32)) * 1e-4
        true_sum += np.asarray(g)
        applied, res = ef_int8_roundtrip(g, res)
        ef_sum += np.asarray(applied)
    # residual is bounded -> sums agree to within one quantization step
    assert np.max(np.abs(true_sum - ef_sum)) <= np.max(np.abs(np.asarray(res))) + 1e-6


def test_psum_int8_collective_single_device():
    """psum_int8 inside shard_map on a 1-device mesh == identity-ish."""
    from repro.compat import make_mesh, shard_map
    from repro.distributed.collectives import psum_int8
    mesh = make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 16),
                    dtype=jnp.float32)

    f = shard_map(lambda a: psum_int8(a, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P(), manual_axes={"pod"})
    y = f(x)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127.0
