"""SQL parser edge cases, expression algebra, optimizer passes in
isolation, and plan-cache LRU semantics."""
import numpy as np
import pytest

from repro.core import dsl
from repro.core import expr as E
from repro.core.logical import Query, validate
from repro.core.optimizer import OptFlags, TableMeta, optimize
from repro.core.plan_cache import PlanCache, bucket_batch


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def test_parse_range_window():
    q = dsl.parse_sql("""
        SELECT AVG(x) OVER w AS a FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     RANGE BETWEEN 30 PRECEDING AND CURRENT ROW)""")
    spec = dict(q.windows)["w"]
    assert spec.range_preceding == 30.0 and spec.rows_preceding is None


def test_parse_scalar_arithmetic_and_functions():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER w AS s,
               LOG(SUM(x) OVER w + 1) AS lg,
               SUM(x) OVER w / COUNT(x) OVER w AS manual_avg
        FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    names = [n for n, _ in q.outputs]
    assert names == ["s", "lg", "manual_avg"]


def test_parse_where_clause():
    q = dsl.parse_sql("""
        SELECT COUNT(x) OVER w AS c FROM t
        WHERE x > 3 AND x <= 10
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    assert q.where is not None
    assert isinstance(q.where, E.BinOp)


def test_parse_rejects_garbage():
    with pytest.raises(SyntaxError):
        dsl.parse_sql("SELECT FROM WINDOW nope")
    # undefined window refs are caught at plan validation (deploy time)
    q = dsl.parse_sql("SELECT SUM(x) OVER missing AS s FROM t")
    with pytest.raises(ValueError, match="undefined window"):
        q.to_logical()
    # mixed partition keys are rejected too
    q2 = dsl.parse_sql("""
        SELECT SUM(x) OVER a AS s, SUM(x) OVER b AS t2 FROM t
        WINDOW a AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW),
               b AS (PARTITION BY other ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    with pytest.raises(ValueError, match="PARTITION BY"):
        q2.to_logical()


def test_expr_fingerprint_stable_and_distinct():
    a = dsl.sum_(dsl.col("x")).over("w").node
    b = dsl.sum_(dsl.col("x")).over("w").node
    c = dsl.sum_(dsl.col("y")).over("w").node
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()


# ---------------------------------------------------------------------------
# optimizer passes in isolation
# ---------------------------------------------------------------------------

def _meta(**kw):
    d = dict(capacity=256, bucket_size=32, n_value_cols=2, has_preagg=True)
    d.update(kw)
    return TableMeta(**d)


def test_constant_folding():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER w * (2 + 3) AS s FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    plan, log = optimize(q.to_logical(), _meta(), OptFlags())
    assert any("constant" in l for l in log), log


def test_window_cost_model_switches_impl():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER w AS s FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 200 PRECEDING AND CURRENT ROW)""")
    # big window + preagg available -> preagg
    plan, _ = optimize(q.to_logical(), _meta(capacity=4096), OptFlags())
    assert dict(plan.window_impl)["w"] == "preagg"
    # no preagg tier -> naive
    plan, _ = optimize(q.to_logical(), _meta(has_preagg=False), OptFlags())
    assert dict(plan.window_impl)["w"] == "naive"


def test_decompose_then_cse_shares_moments():
    q = dsl.parse_sql("""
        SELECT AVG(x) OVER w AS a, STD(x) OVER w AS sd,
               SUM(x) OVER w AS s
        FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)""")
    plan, log = optimize(q.to_logical(), _meta(), OptFlags())
    # AVG -> SUM/COUNT and STD -> moments share the SUM aggregate
    uniq = set()
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            uniq.add(agg.fingerprint())
    assert len(uniq) <= 3, uniq     # sum, sumsq(x*x), count


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_bucket_batch_monotone():
    prev = 0
    for n in range(1, 300):
        b = bucket_batch(n)
        assert b >= n
        assert b >= prev or n <= prev
        prev = b
    assert bucket_batch(5000) == 8192


def test_plan_cache_lru_eviction():
    cache = PlanCache(max_entries=2)
    calls = []

    def mk(tag):
        def make():
            calls.append(tag)
            return lambda: tag
        return make

    cache.get_or_compile("a", mk("a"))
    cache.get_or_compile("b", mk("b"))
    cache.get_or_compile("a", mk("a"))       # refresh a
    cache.get_or_compile("c", mk("c"))       # evicts b (LRU)
    cache.get_or_compile("a", mk("a"))       # still cached
    cache.get_or_compile("b", mk("b"))       # recompiles
    assert calls == ["a", "b", "c", "b"]
    assert cache.stats.evictions >= 1
    assert cache.stats.hits == 2


def test_plan_cache_disabled_always_compiles():
    cache = PlanCache(enabled=False)
    n = {"c": 0}

    def make():
        n["c"] += 1
        return lambda: None

    cache.get_or_compile("k", make)
    cache.get_or_compile("k", make)
    assert n["c"] == 2


# ---------------------------------------------------------------------------
# parser edge cases (ISSUE 2 satellites) + plan-cache invalidation/tags
# ---------------------------------------------------------------------------

def test_parse_mixed_rows_and_range_windows():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER wr AS s, AVG(x) OVER wt AS a FROM t
        WINDOW wr AS (PARTITION BY k ORDER BY ts
                      ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
               wt AS (PARTITION BY k ORDER BY ts
                      RANGE BETWEEN 60 PRECEDING AND CURRENT ROW)""")
    specs = dict(q.windows)
    assert specs["wr"].rows_preceding == 10
    assert specs["wr"].range_preceding is None
    assert specs["wt"].range_preceding == 60.0
    assert specs["wt"].rows_preceding is None
    q.to_logical()                                 # validates cleanly


def test_parse_predict_expression_arguments():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER w AS s,
               PREDICT(m, s + 1, COUNT(x) OVER w * 2, k) AS p
        FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    assert q.predict is not None and q.predict.model == "m"
    names = [n for n, _ in q.outputs]
    # expression and raw-column args materialise as hidden outputs
    assert all(f.startswith("__pred_arg") for f in q.predict.features)
    assert set(q.predict.features) <= set(names)
    # the raw request column `k` became Col-valued hidden output
    assert dict(q.outputs)[q.predict.features[2]] == E.Col("k")
    # the alias `s` was substituted by its defining aggregate
    synth = dict(q.outputs)[q.predict.features[0]]
    assert any(a.func == E.AggFunc.SUM for a in E.collect_aggs(synth))
    q.to_logical()


def test_where_windowed_alias_rejected_clearly():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER w AS s FROM t
        WHERE s > 3
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    with pytest.raises(ValueError, match="SELECT alias"):
        q.to_logical()
    # plain derived aliases are just as out-of-scope in WHERE
    qd = dsl.parse_sql("""
        SELECT x * 2 AS d, SUM(x) OVER w AS s FROM t
        WHERE d > 0
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    with pytest.raises(ValueError, match="SELECT alias"):
        qd.to_logical()
    # identity aliases still name the event column (legal)
    qi = dsl.parse_sql("""
        SELECT x, COUNT(x) OVER w AS c FROM t
        WHERE x > 0
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    qi.to_logical()
    q2 = dsl.parse_sql("""
        SELECT COUNT(x) OVER w AS c FROM t
        WHERE SUM(x) OVER w > 3
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    with pytest.raises(ValueError, match="window aggregates"):
        q2.to_logical()


def test_undefined_over_window_error_names_alternatives():
    q = dsl.parse_sql("""
        SELECT SUM(x) OVER nope AS s FROM t
        WINDOW w AS (PARTITION BY k ORDER BY ts
                     ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    with pytest.raises(ValueError,
                       match=r"undefined window 'nope'.*'w'"):
        q.to_logical()


def test_plan_cache_invalidate_and_tag_stats():
    pc = PlanCache(max_entries=8)
    for fp, b in [("planA", 1), ("planA", 2), ("planB", 1)]:
        pc.get_or_compile((fp, b), lambda: (lambda: None), tag=f"d@{fp}")
    pc.get_or_compile(("planA", 1), lambda: (lambda: None),
                      tag="d@planA")               # hit
    assert pc.tag_stats("d@planA").misses == 2
    assert pc.tag_stats("d@planA").hits == 1
    assert pc.invalidate("planA") == 2
    assert len(pc) == 1
    assert pc.stats.invalidations == 2
    assert pc.invalidate("nope") == 0
    pc.record_hit("d@planB")                       # handle-owned hit
    assert pc.tag_stats("d@planB").hits == 1
    assert pc.stats.hits == 2
