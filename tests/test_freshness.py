"""Data-plane freshness + SLO tier (DESIGN.md §14): watermark-stamped
snapshots, per-request feature age, ingest-to-visible latency, exact
cross-shard sketch merging, burn-rate SLO alerting delivered into the
control plane, and the flight recorder's dump-on-breach path.

The acceptance pair:

* an end-to-end freshness test — a disordered streamed load on BOTH
  shard backends where the served feature age matches the injected
  watermark lag and the cross-shard merged age sketch equals the
  single-engine sketch bit for bit;
* an SLO burn-rate test — an injected latency regression flips the SLO
  to ALERTING within the fast window, the alert lands in
  ``ControlPlane.tick()`` as ``slo_burning`` (steering a knob), the
  flight ring is dumped to JSONL with the offending trace ids, and the
  SLO recovers to OK once the regression clears.
"""
import json
import math
import os
import time

import numpy as np
import pytest

from repro.control.knobs import KnobConfig, KnobController
from repro.control.plane import ControlPlane
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.core.results import STATUS_OK, RequestContext
from repro.featurestore.table import TableSchema
from repro.obs.flight import FlightRecorder
from repro.obs.freshness import FreshnessTracker
from repro.obs.sketch import QuantileSketch, RollingSketch
from repro.obs.slo import ALERTING, OK, SLOEngine, SLOSpec
from repro.shard import ShardConfig, ShardedEngine

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""
SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))

N_KEYS = 16
N_TICKS = 40            # event-time grid 0..39; watermark = 39.0


def _round_robin_events(seed=0, shuffle=False):
    """Every key gets exactly one event per event-time tick, so EVERY
    shard's watermark equals the global max tick — the construction that
    makes sharded freshness bit-comparable to a single engine. With
    ``shuffle`` the arrival order is disordered (streamed loads only:
    direct ``insert`` requires per-key ordered timestamps)."""
    rng = np.random.default_rng(seed)
    keys, ts = np.meshgrid(np.arange(N_KEYS), np.arange(N_TICKS))
    keys, ts = keys.ravel(), ts.ravel().astype(np.float64)
    rows = np.stack([rng.normal(size=keys.size),
                     rng.integers(0, 4, keys.size)], -1).astype(np.float32)
    if not shuffle:
        return keys, ts, rows
    order = rng.permutation(keys.size)       # disordered arrival
    return keys[order], ts[order], rows[order]


def _stream_into(eng, keys, ts, rows, lateness=1000.0):
    pipe = eng.attach_stream("events", lateness=lateness,
                             flush_interval_s=0.001)
    pipe.push_batch(keys.tolist(), ts.tolist(), rows)
    pipe.flush()
    return pipe


def _mk(backend=None):
    eng = (Engine(OptFlags()) if backend is None
           else ShardedEngine(ShardConfig(n_shards=3), backend=backend))
    eng.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    return eng


def _sketch_core(d):
    """The bit-for-bit comparable part of a sketch dict (``sum`` is
    excluded: float addition order differs across merge topologies)."""
    return {k: d[k] for k in ("rel_err", "pos", "neg", "zero", "count",
                              "min", "max")}


# ===================================================== freshness stamps
def test_table_watermark_and_frame_stamp():
    eng = _mk()
    keys, ts, rows = _round_robin_events()
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    assert eng.tables["events"].watermark == float(N_TICKS - 1)
    snap = eng.tables["events"].snapshot()
    assert snap.watermark == float(N_TICKS - 1)
    assert snap.published_at > 0.0
    eng.deploy("q", SQL)
    fr = eng.request("q", [0, 1, 2], [100.0, 200.0, 150.0])
    assert fr.watermark == float(N_TICKS - 1)
    # batch age = max over rows of (request event-ts - watermark)
    assert fr.feature_age == pytest.approx(200.0 - (N_TICKS - 1))
    assert fr.row(1).feature_age == fr.feature_age
    eng.close()


def test_unserved_table_has_no_watermark_stamp():
    eng = _mk()
    eng.deploy("q", SQL)
    fr = eng.request("q", [0], [5.0])
    assert fr.watermark is None and fr.feature_age is None
    exp = eng.freshness_export()
    assert math.isnan(FreshnessTracker.worst_age_p99(exp))
    eng.close()


def test_ingest_to_visible_latency_recorded():
    """Events pushed, then flushed after an injected delay: the i2v
    histogram must cover every event and sit at/above the injected
    delay (exact to within one flush interval + scheduling slack)."""
    eng = _mk()
    keys, ts, rows = _round_robin_events(shuffle=True)
    pipe = eng.attach_stream("events", lateness=1000.0,
                             flush_interval_s=30.0)   # manual flush only
    pipe.push_batch(keys.tolist(), ts.tolist(), rows)
    delay = 0.15
    time.sleep(delay)
    pipe.flush()
    snap = eng.freshness_snapshot()["events"]
    assert snap["ingested"] == keys.size
    i2v = QuantileSketch.from_dict(snap["i2v_sketch"])
    assert i2v.count == keys.size
    assert i2v.percentile(50) >= delay * 0.9          # waited at least
    assert i2v.percentile(99) < delay + 5.0           # no runaway clock
    exp = eng.freshness_export()
    assert exp["events/ingest_visible_p50_s"] >= delay * 0.9
    # per-column ingest sketches + key cardinality ride along
    assert exp["events/keys_est"] == pytest.approx(N_KEYS)
    assert math.isfinite(exp["events/ingest_amount_p50"])
    eng.close()


# ============================= acceptance: e2e freshness, both backends
@pytest.mark.parametrize("backend", ["inprocess", "process"])
def test_feature_age_and_merged_sketch_bit_for_bit(backend):
    """Disordered streamed load into a reference Engine and a 3-shard
    ShardedEngine: frame freshness stamps agree exactly, and the
    cross-shard MERGED age sketch equals the single-engine sketch bit
    for bit (same buckets, same counts, same p99)."""
    keys, ts, rows = _round_robin_events(shuffle=True)
    ref, se = _mk(), _mk(backend)
    try:
        _stream_into(ref, keys, ts, rows)
        _stream_into(se, keys, ts, rows)
        ref.deploy("q", SQL)
        se.deploy("q", SQL)
        wm = float(N_TICKS - 1)
        rng = np.random.default_rng(7)
        for b in range(4):
            rk = rng.integers(0, N_KEYS, 16).tolist()
            rt = (np.full(16, 100.0 + b, np.float32)
                  + rng.integers(0, 5, 16).astype(np.float32)).tolist()
            fa = ref.request("q", rk, rt)
            fs = se.request("q", rk, rt)
            assert (fa.status == STATUS_OK).all()
            assert np.array_equal(fa.status, fs.status)
            # stamps: same watermark, same (max-over-rows) age — the
            # injected lag is request ts - wm, exact in event time
            assert fa.watermark == fs.watermark == wm
            assert fa.feature_age == fs.feature_age
            assert fa.feature_age == pytest.approx(max(rt) - wm)
        ref_snap = ref.freshness_snapshot()["events"]
        se_snap = se.freshness_snapshot()["events"]
        assert se_snap["watermark"] == ref_snap["watermark"] == wm
        assert se_snap["ingested"] == ref_snap["ingested"] == keys.size
        assert se_snap["serve_rows"] == ref_snap["serve_rows"] == 64
        # THE bit-for-bit contract: merged-across-shards age sketch ==
        # the single engine's (pad rows excluded via n_live, so equal
        # request multisets produce equal bucket maps)
        a, m = ref_snap["age_sketch"], se_snap["age_sketch"]
        assert _sketch_core(a) == _sketch_core(m)
        assert (QuantileSketch.from_dict(a).percentile(99)
                == QuantileSketch.from_dict(m).percentile(99))
        # per-column ingest sketches merge exactly too
        for col in ("amount", "mkey"):
            assert _sketch_core(ref_snap["columns"][col]) == \
                _sketch_core(se_snap["columns"][col])
    finally:
        ref.close()
        se.close()


def test_freshness_merge_matches_single_tracker():
    """Unit half of the acceptance: merge(shard snapshots) == the
    tracker that observed the union, and watermarks take the MIN."""
    rng = np.random.default_rng(3)
    ages = rng.gamma(2.0, 5.0, 4096)
    whole, a, b = (FreshnessTracker() for _ in range(3))
    whole.observe_age("t", ages)
    a.observe_age("t", ages[:1500])
    b.observe_age("t", ages[1500:])
    sa, sb = a.snapshot(), b.snapshot()
    sa["t"]["watermark"], sb["t"]["watermark"] = 40.0, 25.0
    merged = FreshnessTracker.merge([sa, None, sb])["t"]
    assert _sketch_core(merged["age_sketch"]) == \
        _sketch_core(whole.snapshot()["t"]["age_sketch"])
    assert merged["watermark"] == 25.0      # slowest shard bounds it
    assert merged["serve_rows"] == 4096


# ===================================================== burn-rate SLOs
def test_slo_engine_multi_window_burn_deterministic():
    """Driven clock: the fast window trips promptly on a regression and
    resolves promptly after it clears; the slow window filters blips."""
    spec = SLOSpec("lat", "latency_p99_s", bound=0.010, budget=0.1,
                   fast_window_s=10.0, slow_window_s=60.0,
                   burn_threshold=2.0)
    slo = SLOEngine([spec])
    t = 0.0
    for _ in range(60):                      # a healthy minute
        assert slo.evaluate({"latency_p99_s": 0.002}, now=t) == []
        t += 1.0
    # one bad blip: fast burn spikes but the SLOW window holds it back
    slo.evaluate({"latency_p99_s": 0.5}, now=t); t += 1.0
    assert slo.state("lat") == OK
    events = []
    for _ in range(12):                      # sustained regression
        events += slo.evaluate({"latency_p99_s": 0.5}, now=t)
        t += 1.0
    assert slo.state("lat") == ALERTING
    assert [e["state"] for e in events] == [ALERTING]
    # deterministic fire time: the slow window (60 samples, budget 0.1,
    # threshold 2.0) needs 12 bad samples -> t = 61 + 11 = 72
    assert events[0]["t"] == 72.0
    for _ in range(11):                      # recovery: fast drains
        events += slo.evaluate({"latency_p99_s": 0.002}, now=t)
        t += 1.0
    assert slo.state("lat") == OK
    assert slo.export()["lat/transitions"] == 2.0
    # missing / non-finite metrics contribute no sample
    n0 = slo.snapshot(now=t)["lat"]["slow_samples"]
    slo.evaluate({}, now=t)
    slo.evaluate({"latency_p99_s": float("nan")}, now=t)
    assert slo.snapshot(now=t)["lat"]["slow_samples"] == n0


def test_slo_spec_validation():
    with pytest.raises(ValueError):
        SLOSpec("x", "m", 1.0, action="page")
    with pytest.raises(ValueError):
        SLOSpec("x", "m", 1.0, budget=0.0)
    with pytest.raises(ValueError):
        SLOSpec("x", "m", 1.0, fast_window_s=60, slow_window_s=30)


# ============================ acceptance: SLO burn -> tick -> flight
def test_slo_burn_alert_into_control_plane_e2e(tmp_path):
    """Injected latency regression: the latency SLO flips to ALERTING
    within the fast window, ``tick()`` folds the active alert into the
    knob controller (``slo_burning`` -> overload backoff even though the
    plain p99 target would not have tripped), the flight ring lands on
    disk with the offending trace ids, and the SLO recovers to OK."""
    eng = _mk()
    keys, ts, rows = _round_robin_events()
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy("q", SQL)
    eng.flight.out_dir = str(tmp_path)
    # short latency window so the regression also CLEARS quickly
    h = eng.handle("q")
    h.metrics.latency_s = RollingSketch(window_s=0.2)
    slo = SLOEngine([SLOSpec("latency", "latency_p99_s", bound=0.5,
                             budget=0.25, fast_window_s=0.6,
                             slow_window_s=0.6, burn_threshold=1.0)])
    plane = ControlPlane(
        eng, "q", replan=False, slo=slo,
        # sky-high plain-p99 target: only the SLO can declare overload
        knobs=KnobController(KnobConfig(target_p99_s=100.0),
                             delay_s=0.004))
    traces = []

    def serve_once():
        tid = f"trace-{len(traces):04d}"
        traces.append(tid)
        eng.request("q", [0, 1, 2, 3], [100.0] * 4,
                    ctx=RequestContext(trace_id=tid))

    for _ in range(3):                       # healthy baseline
        serve_once()
        r = plane.tick()
        assert r["slo"]["alerting"] == []
        time.sleep(0.03)
    assert slo.state("latency") == OK

    deadline = time.time() + 10.0
    while slo.state("latency") == OK and time.time() < deadline:
        serve_once()
        h.metrics.observe_latency(2.0)       # the injected regression
        plane.tick()
        time.sleep(0.05)
    assert slo.state("latency") == ALERTING  # fired within fast window

    # one more burning tick pair -> hysteresis met -> knob backoff
    burn_reports = []
    for _ in range(3):
        serve_once()
        h.metrics.observe_latency(2.0)
        burn_reports.append(plane.tick())
        time.sleep(0.05)
    assert any(r["load"]["slo_burning"] for r in burn_reports)
    assert plane.knobs.knobs["delay_s"] < 0.004
    moves = [d for r in plane.reports for d in r["knob_decisions"]]
    assert any(d["knob"] == "delay_s" and "overload" in d["reason"]
               for d in moves)

    # flight ring hit the disk on the OK->ALERTING transition, and it
    # carries the serve records' trace ids from the burning interval
    assert plane.flight is eng.flight and eng.flight.dumps
    recs = [json.loads(line)
            for line in open(eng.flight.dumps[0], encoding="utf-8")]
    assert recs[0]["kind"] == "dump" and "slo-latency" in \
        os.path.basename(eng.flight.dumps[0])
    kinds = {r["kind"] for r in recs}
    assert "slo_transition" in kinds and "serve" in kinds
    dumped_traces = {r.get("trace") for r in recs if r["kind"] == "serve"}
    assert dumped_traces & set(traces)

    deadline = time.time() + 10.0            # recovery: regression gone
    while slo.state("latency") == ALERTING and time.time() < deadline:
        serve_once()
        plane.tick()
        time.sleep(0.05)
    assert slo.state("latency") == OK
    assert not plane.reports[-1]["load"]["slo_burning"]
    eng.close()


# ================================================================ drift
def test_drift_detector_tp_and_fp():
    """Same serving distribution after pinning -> no drift (FP check);
    a genuinely shifted output distribution -> PSI over threshold (TP)."""
    eng = _mk()
    keys, ts, rows = _round_robin_events()
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy("q", SQL)
    rng = np.random.default_rng(11)

    def serve(lo, hi, n_batches=6):
        for _ in range(n_batches):
            rk = rng.integers(0, N_KEYS, 16).tolist()
            rt = rng.uniform(lo, hi, 16).astype(np.float32).tolist()
            eng.request("q", rk, rt)

    serve(100.0, 200.0)
    assert eng.pin_drift_reference() == ["c", "s"]
    serve(100.0, 200.0)                      # same workload again
    rep = eng.drift_report()
    assert not any(r["drifted"] for r in rep.values()), rep
    assert rep["s"]["psi"] < 0.25
    # inject upstream drift: fresh events whose amounts jump to ~N(50,1)
    # — the windowed SUM shifts, the windowed COUNT must not
    k2, t2 = np.meshgrid(np.arange(N_KEYS), np.arange(N_TICKS,
                                                      N_TICKS + 20))
    k2, t2 = k2.ravel(), t2.ravel().astype(np.float64)
    r2 = np.stack([rng.normal(50.0, 1.0, k2.size),
                   rng.integers(0, 4, k2.size)], -1).astype(np.float32)
    eng.insert("events", k2.tolist(), t2.tolist(), r2)
    serve(2000.0, 2100.0, n_batches=12)
    rep2 = eng.drift_report()
    assert rep2["s"]["drifted"] and rep2["s"]["psi"] > 0.25
    assert not rep2["c"]["drifted"]          # count distribution held
    exp = eng.drift_export()
    assert exp["s/drifted"] == 1.0
    eng.close()


@pytest.mark.parametrize("backend", ["inprocess", "process"])
def test_drift_pin_and_merge_across_shards(backend):
    se = _mk(backend)
    keys, ts, rows = _round_robin_events()
    try:
        se.insert("events", keys.tolist(), ts.tolist(), rows)
        se.deploy("q", SQL)
        rng = np.random.default_rng(13)
        batches = [rng.integers(0, N_KEYS, 16).tolist() for _ in range(4)]
        for rk in batches:
            se.request("q", rk, [150.0] * 16)
        assert se.pin_drift_reference() == ["c", "s"]
        for rk in batches:                   # identical request multiset
            se.request("q", rk, [150.0] * 16)
        rep = se.drift_report()
        assert set(rep) == {"c", "s"}
        assert rep["s"]["live_count"] == 64 and rep["s"]["ref_count"] == 64
        assert rep["s"]["psi"] == 0.0        # identical dist, exact merge
        assert not rep["s"]["drifted"]
    finally:
        se.close()


# ======================================================= flight recorder
def test_flight_recorder_ring_dump_and_rate_limit(tmp_path):
    fl = FlightRecorder(capacity=8, out_dir=str(tmp_path),
                        min_dump_interval_s=60.0)
    fl.set_context(delay_s=0.004)
    fl.set_context(delay_s=0.004)            # unchanged: no record
    for i in range(20):
        fl.record("serve", trace=f"t{i}", rows=4)
    assert len(fl) == 8                      # bounded: newest only
    p1 = fl.dump("slo-latency")
    assert p1 and os.path.exists(p1)
    assert fl.dump("again") is None          # rate-limited
    assert fl.dump("forced", force=True)     # ... unless forced
    lines = [json.loads(ln) for ln in open(p1, encoding="utf-8")]
    assert lines[0]["kind"] == "dump"
    assert lines[0]["context"] == {"delay_s": 0.004}
    serves = [ln for ln in lines if ln["kind"] == "serve"]
    assert [s["trace"] for s in serves] == [f"t{i}" for i in range(12, 20)]
    assert fl.stats()["dumps"] == 2.0


def test_sharded_worker_down_dumps_flight(tmp_path):
    """A worker death is a flight-dump trigger: the parent records the
    worker_down marker and persists the ring."""
    import signal
    se = _mk("process")
    keys, ts, rows = _round_robin_events()
    try:
        se.flight.out_dir = str(tmp_path)
        se.insert("events", keys.tolist(), ts.tolist(), rows)
        se.deploy("q", SQL)
        se.request("q", list(range(8)), [100.0] * 8)
        os.kill(se.shards[1].proc.pid, signal.SIGKILL)
        deadline = time.time() + 90.0
        while not se.flight.dumps and time.time() < deadline:
            time.sleep(0.05)
        assert se.flight.dumps
        recs = [json.loads(ln)
                for ln in open(se.flight.dumps[0], encoding="utf-8")]
        assert any(r["kind"] == "worker_down" for r in recs)
        assert any(r["kind"] == "serve" for r in recs)
    finally:
        se.close()
