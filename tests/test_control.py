"""Adaptive control plane (DESIGN.md §10): telemetry snapshots,
cost-model calibration, knob AIMD + replay determinism, admission
deadline fixes, and the closed replan loop over versioned hot-swap."""
import json
import math
import threading
import time

import numpy as np
import pytest

from repro.control import (ControlPlane, CostCalibrator, KnobConfig,
                           KnobController, LoadObservation,
                           MetricsCollector, Replanner, RingSeries,
                           differs_materially, plan_element_profile)
from repro.core.engine import Engine, EngineStats, HandleMetrics
from repro.core.optimizer import CostModel, OptFlags
from repro.core.plan_cache import CacheStats
from repro.core.results import (STATUS_OK, STATUS_SHED, RequestContext)
from repro.featurestore.table import TableSchema
from repro.shard.resource import AdmissionConfig, ResourceManager

SQL = """
SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)
"""

JOIN_SQL = """
SELECT SUM(amount) OVER w AS s,
       merchants.rating AS rating
FROM events
LAST JOIN merchants ORDER BY mts ON merchant
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)
"""


def make_engine(flags=OptFlags(), n_events=400, n_keys=16, seed=0):
    eng = Engine(flags)
    eng.create_table(TableSchema("events", key_col="user", ts_col="ts",
                                 value_cols=("amount", "lat", "lon")),
                     max_keys=64, capacity=256, bucket_size=32)
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0, 1000, n_events)).astype(np.float32)
    rows = rng.normal(0, 2, size=(n_events, 3)).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    return eng


def make_join_engine(seed=0):
    eng = Engine(OptFlags())
    eng.create_table(TableSchema("events", key_col="user", ts_col="ts",
                                 value_cols=("amount", "merchant")),
                     max_keys=32, capacity=256, bucket_size=32)
    eng.create_table(TableSchema("merchants", key_col="merchant",
                                 ts_col="mts",
                                 value_cols=("rating", "risk")),
                     max_keys=16, capacity=64, bucket_size=8)
    rng = np.random.default_rng(seed)
    n = 200
    keys = rng.integers(0, 8, n)
    ts = np.sort(rng.uniform(0, 1000, n)).astype(np.float32)
    mids = rng.integers(0, 4, n)
    rows = np.stack([rng.normal(0, 2, n),
                     mids.astype(np.float64)], -1).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    return eng


def serve(eng, name, n_batches=8, B=8, seed=1, rows=False):
    rng = np.random.default_rng(seed)
    frames = []
    for _ in range(n_batches):
        rk = rng.integers(0, 8, B)
        rt = np.sort(rng.uniform(1100, 1500, B)).astype(np.float32)
        rr = None
        if rows:
            rr = np.stack([rng.normal(0, 2, B),
                           rng.integers(0, 4, B).astype(np.float64)],
                          -1).astype(np.float32)
        frames.append(eng.request(name, rk.tolist(), rt.tolist(), rr))
    return frames


# ---------------------------------------------------------------- telemetry
def test_ring_series_bounded_fifo():
    s = RingSeries(maxlen=4)
    for i in range(10):
        s.append(float(i), float(i * 2))
    assert len(s) == 4
    assert s.values() == [12.0, 14.0, 16.0, 18.0]   # newest 4 win
    assert s.last() == 18.0
    assert s.to_json() == {"t": [6.0, 7.0, 8.0, 9.0],
                           "v": [12.0, 14.0, 16.0, 18.0]}


def test_engine_stats_snapshot_delta():
    st = EngineStats()
    base = st.snapshot()
    st.n_requests += 10
    st.exec_s += 0.5
    st.kernel_launches += 3
    d = st.delta(base)
    assert d["n_requests"] == 10 and d["kernel_launches"] == 3
    assert d["exec_s"] == pytest.approx(0.5)
    assert d["n_batches"] == 0
    # snapshot is a copy: mutating the source later can't change it
    snap2 = st.snapshot()
    st.n_requests += 5
    assert snap2["n_requests"] == 10
    # deltas never go negative even against a newer baseline
    assert st.delta(st.snapshot())["n_requests"] == 0


def test_cache_stats_snapshot():
    cs = CacheStats(hits=3, misses=1, compile_seconds=0.25)
    snap = cs.snapshot()
    assert snap["hits"] == 3 and snap["hit_rate"] == pytest.approx(0.75)
    cs.hits += 100
    assert snap["hits"] == 3
    json.dumps(snap)


def test_handle_metrics_latency_sketch():
    """Latency percentiles come from a bounded rolling quantile sketch:
    no raw-sample reservoir, a monotonic sample count (the replanner's
    health gate keys on it advancing), and a guaranteed relative-error
    bound instead of FIFO displacement."""
    m = HandleMetrics()
    assert math.isnan(m.latency_percentile(99))      # empty = no tail
    for i in range(600):
        m.observe_latency(0.001 * (i + 1))
    assert len(m.latency_s) == 600                   # monotonic, unbounded
    p99 = m.latency_percentile(99)
    assert p99 == pytest.approx(0.001 * 595, rel=0.05)
    snap = m.snapshot()
    assert snap["latency_samples"] == 600
    assert snap["latency_p99_s"] >= snap["latency_p50_s"]
    # the sketch itself rides along for exact cross-shard merging
    assert snap["latency_sketch"]["kind"] == "qsketch"
    json.dumps(snap)


def test_collector_samples_and_snapshot_json():
    eng = make_engine()
    eng.deploy("f", SQL)
    col = MetricsCollector(eng)
    s0 = col.sample()
    serve(eng, "f", n_batches=5)
    s1 = col.sample()
    # interval deltas, not cumulative totals
    assert s1["deployments"]["f"]["delta"]["batches"] == 5
    assert s1["deployments"]["f"]["delta"]["requests"] == 40
    assert s1["engine_delta"]["n_batches"] >= 5
    assert s0["cache"]["hits"] <= s1["cache"]["hits"]
    assert s1["deployments"]["f"]["joins"] == {}       # join-free plan
    snap = col.snapshot()
    json.dumps(snap)                                   # fully serializable
    assert snap["n_samples"] == 2
    assert "dep.f.p99_s" in snap["series"]
    eng.close()


def test_collector_samples_join_staleness():
    eng = make_join_engine()
    eng.insert("merchants", [0, 1, 2, 3], [50.0] * 4,
               np.asarray([[m, m * 0.1] for m in range(4)], np.float32))
    eng.deploy("f", JOIN_SQL)
    col = MetricsCollector(eng)
    serve(eng, "f", n_batches=3, rows=True)
    s = col.sample()
    st = s["deployments"]["f"]["joins"]["merchants"]
    assert st["probes"] == 24
    assert 0.0 <= st["match_rate"] <= 1.0
    assert "dep.f.join.merchants.match_rate" in col.series
    json.dumps(s)
    eng.close()


# ------------------------------------------------- join staleness reservoir
def test_join_staleness_empty_reservoir_percentiles():
    """No probes yet: percentile queries are NaN (not 0, not a crash) and
    the match rate with zero probes is 0, not a division error."""
    eng = make_join_engine()
    dep = eng.deploy("f", JOIN_SQL)
    st = dep.join_staleness()["merchants"]
    assert st["probes"] == 0 and st["matches"] == 0
    assert st["match_rate"] == 0.0
    assert math.isnan(st["age_p50"]) and math.isnan(st["age_p99"])
    assert st["age_samples"] == 0
    eng.close()


def test_join_staleness_zero_probe_rows_after_serving():
    """Serving with every probe missing keeps matches at 0 but counts
    probes — the match rate must be a true 0.0, not NaN."""
    eng = make_join_engine()
    eng.insert("merchants", [0], [100.0], np.asarray([[1.0, 0.5]],
                                                     np.float32))
    dep = eng.deploy("f", JOIN_SQL)
    rng = np.random.default_rng(2)
    B = 8
    rk = rng.integers(0, 8, B)
    rt = np.full(B, 1200.0, np.float32)
    # request rows probe merchant id 9 — never published
    rr = np.stack([rng.normal(0, 2, B), np.full(B, 9.0)],
                  -1).astype(np.float32)
    eng.request("f", rk.tolist(), rt.tolist(), rr)
    st = dep.join_staleness()["merchants"]
    assert st["probes"] == B and st["matches"] == 0
    assert st["match_rate"] == 0.0
    assert math.isnan(st["age_p99"])                   # no matched ages
    eng.close()


def test_join_age_sketch_determinism_and_bounded_state():
    """The age reservoir is a log-bucketed quantile sketch: every age
    ever observed counts (no FIFO displacement), state stays bounded
    (bucket count grows with the value RANGE, not the sample count), and
    two identical fixed-seed runs agree bit for bit."""
    def run():
        eng = make_join_engine(seed=3)
        eng.insert("merchants", [0, 1, 2, 3], [50.0] * 4,
                   np.asarray([[m, m * 0.1] for m in range(4)],
                              np.float32))
        dep = eng.deploy("f", JOIN_SQL)
        h = eng.handle("f")
        ages = np.arange(1012, dtype=np.float64)
        res = {"__join_match_merchants": np.ones(len(ages), np.float32),
               "__join_age_merchants": ages}
        h._record_join_stats(res, len(ages))
        sk = h._join_ages["merchants"]
        st = dep.join_staleness()["merchants"]
        eng.close()
        return sk.to_dict(), st

    d1, st1 = run()
    d2, st2 = run()
    assert d1 == d2                                     # deterministic
    assert st1["age_samples"] == st2["age_samples"] == 1012
    assert st1["age_p99"] == st2["age_p99"]
    # rel-err bound holds at the tail; far fewer buckets than samples
    assert st1["age_p99"] == pytest.approx(0.99 * 1011, rel=0.05)
    assert len(d1["pos"]) < 1012 // 2
    json.dumps(st1)                 # snapshot (sketch incl.) serializes


# --------------------------------------------------------------- calibrator
def test_calibrator_under_sampled_returns_none():
    cal = CostCalibrator(min_samples=8)
    for _ in range(7):
        cal.observe("scan", 100.0, 0.001)
    assert cal.fit() is None


def test_calibrator_normalizes_to_scan():
    cal = CostCalibrator(min_samples=4)
    for _ in range(6):
        cal.observe("scan", 200.0, 0.0002)    # 1e-6 s/el
        cal.observe("preagg", 100.0, 0.0005)  # 5e-6 s/el
        cal.observe("join", 50.0, 0.0001)     # 2e-6 s/el
    m = cal.fit()
    assert m.scan_el == pytest.approx(1.0)
    assert m.preagg_el == pytest.approx(5.0)
    assert m.join_el == pytest.approx(2.0)
    assert differs_materially(m, CostModel())
    assert not differs_materially(m, m)


def test_calibrator_per_table_join_weights():
    cal = CostCalibrator(min_samples=4)
    for _ in range(6):
        cal.observe("scan", 100.0, 0.0001)
        cal.observe("join", 50.0, 0.0001, table="hot")   # 2e-6 s/el
        cal.observe("join", 50.0, 0.0004, table="cold")  # 8e-6 s/el
    m = cal.fit()
    w = dict(m.table_el)
    # per-table multipliers are relative to the pooled join coefficient
    assert w["cold"] / w["hot"] == pytest.approx(4.0)
    # and they feed straight into the join cost the optimizer compares
    assert (m.table_weight("cold") / m.table_weight("hot")
            == pytest.approx(4.0))


def test_cost_model_default_reproduces_seed_costs():
    """The calibrated-model plumbing must be invisible at defaults: same
    plan decisions as the seed's hard-coded constants."""
    eng = make_engine()
    dep = eng.deploy("f", SQL)
    assert eng.cost_model == CostModel()
    assert dict(dep.plan.window_impl)["w"] == "preagg"
    eng.close()


def test_plan_element_profile_kinds():
    eng = make_engine()
    dep = eng.deploy("f", SQL)
    prof = plan_element_profile(dep)
    assert prof.get("preagg", 0) > 0        # the deployed impl
    assert "join" not in prof
    eng.close()


# ------------------------------------------------------------------- knobs
def test_knob_hysteresis_one_bad_tick_is_ignored():
    c = KnobController(KnobConfig(hysteresis_ticks=2), delay_s=0.004)
    hot = LoadObservation(p99_s=0.5, shed=1)
    calm = LoadObservation(p99_s=0.005)
    assert c.step(hot) == []                 # 1 breach < hysteresis
    assert c.step(calm) == []                # breach streak reset
    assert c.step(hot) == []
    decisions = c.step(hot)                  # 2 consecutive -> act
    assert len(decisions) == 1
    assert decisions[0].knob == "delay_s"
    assert decisions[0].new == pytest.approx(0.002)     # x0.5 backoff


def test_knob_aimd_bounds_and_directions():
    cfg = KnobConfig(hysteresis_ticks=1, min_delay_s=0.001,
                     max_delay_s=0.003, max_dispatch_rows=300)
    c = KnobController(cfg, delay_s=0.003, dispatch_rows=256,
                       max_inflight=8)
    hot = LoadObservation(p99_s=1.0, shed=3, rejected=1)
    for _ in range(5):
        c.step(hot)
    assert c.knobs["delay_s"] == pytest.approx(0.001)   # clamped at min
    assert c.knobs["max_inflight"] > 8                  # backpressure+
    cool = LoadObservation(p99_s=0.0001)
    for _ in range(10):
        c.step(cool)
    assert c.knobs["delay_s"] == pytest.approx(0.003)   # clamped at max
    assert c.knobs["dispatch_rows"] == 300              # clamped at max


def test_knob_decision_log_replays_identically():
    cfg = KnobConfig(hysteresis_ticks=2)
    init = {"delay_s": 0.002, "dispatch_rows": 128, "max_inflight": 8}
    c = KnobController(cfg, seed=42, **init)
    rng = np.random.default_rng(42)
    for _ in range(40):
        c.step(LoadObservation(
            p99_s=float(rng.uniform(0.001, 0.05)),
            queue_depth=int(rng.integers(0, 4)),
            shed=int(rng.integers(0, 2)),
            rejected=int(rng.integers(0, 2)),
            requests=int(rng.integers(1, 100))))
    assert any(e["decisions"] for e in c.log)           # it did act
    replayed = KnobController.replay(cfg, 42, init, c.log)
    assert replayed.log == c.log                        # bit-for-bit
    json.dumps(c.log)                                   # serializable


# --------------------------------------------------------------- admission
def test_admit_deadlined_request_sheds_instead_of_raising():
    """Regression (ISSUE 6 satellite): a blocked admit with a deadline
    must time out AT the deadline and return shed — it used to raise
    backpressure when the deadline exceeded ``admit_timeout_s``, and the
    caller had no shed frame to return."""
    mgr = ResourceManager(AdmissionConfig(max_inflight=1,
                                          admit_timeout_s=0.15))
    hold = mgr.admit("d", None)             # occupy the only slot
    ctx = RequestContext.with_timeout(10.0)  # deadline far beyond the cap
    t0 = time.monotonic()
    adm = mgr.admit("d", ctx)
    waited = time.monotonic() - t0
    assert adm.shed                          # shed, NOT RuntimeError
    assert waited < 1.0                      # gave up at the cap, not 10 s
    assert mgr.metrics()["shed_deadline"] == 1
    hold.release()


def test_admit_sheds_at_the_request_deadline_not_later():
    mgr = ResourceManager(AdmissionConfig(max_inflight=1,
                                          admit_timeout_s=5.0))
    hold = mgr.admit("d", None)
    ctx = RequestContext.with_timeout(0.1)
    t0 = time.monotonic()
    adm = mgr.admit("d", ctx)
    waited = time.monotonic() - t0
    assert adm.shed
    assert 0.05 < waited < 1.0               # ~the deadline, not the cap
    hold.release()


def test_admit_deadline_less_still_raises_backpressure():
    mgr = ResourceManager(AdmissionConfig(max_inflight=1,
                                          admit_timeout_s=0.05))
    hold = mgr.admit("d", None)
    with pytest.raises(RuntimeError, match="admission control"):
        mgr.admit("d", None)
    assert mgr.metrics()["rejected_inflight"] == 1
    hold.release()


def test_admit_min_service_budget_sheds_doomed_work():
    """A request admitted with less budget than it could possibly finish
    in would only be shed later at lane dequeue — the budget floor sheds
    it at the door instead."""
    mgr = ResourceManager(AdmissionConfig(max_inflight=4,
                                          min_service_budget_s=0.2))
    adm = mgr.admit("d", RequestContext.with_timeout(0.05))   # < floor
    assert adm.shed
    adm2 = mgr.admit("d", RequestContext.with_timeout(5.0))   # plenty
    assert not adm2.shed
    adm2.release()


def test_admit_release_wakes_waiters_across_deployments():
    """notify_all regression: a freed slot must wake waiters of OTHER
    deployment names sharing the condition, not a single arbitrary one."""
    mgr = ResourceManager(AdmissionConfig(max_inflight=1,
                                          admit_timeout_s=5.0))
    hold_a = mgr.admit("a", None)
    hold_b = mgr.admit("b", None)
    results = {}

    def waiter(name):
        adm = mgr.admit(name, RequestContext.with_timeout(3.0))
        results[name] = adm
        adm.release()

    ts = [threading.Thread(target=waiter, args=(n,)) for n in ("a", "b")]
    for t in ts:
        t.start()
    time.sleep(0.05)
    hold_b.release()
    hold_a.release()
    for t in ts:
        t.join(timeout=3.0)
    assert set(results) == {"a", "b"}
    assert not results["a"].shed and not results["b"].shed


def test_admission_reconfigure_unblocks_live_waiter():
    mgr = ResourceManager(AdmissionConfig(max_inflight=1,
                                          admit_timeout_s=5.0))
    hold = mgr.admit("d", None)
    got = {}

    def waiter():
        got["adm"] = mgr.admit("d", RequestContext.with_timeout(3.0))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    mgr.reconfigure(max_inflight=2)          # loosen the bound live
    t.join(timeout=3.0)
    assert not t.is_alive()
    assert not got["adm"].shed               # admitted under the new bound
    got["adm"].release()
    hold.release()


# ------------------------------------------------------------ batcher knobs
def test_batcher_reconfigure_and_introspection():
    from repro.serving.batcher import BatcherConfig, DynamicBatcher
    done = threading.Event()

    def slow_serve(keys, ts, payloads):
        done.wait(0.2)
        return {"x": np.zeros(len(keys), np.float32)}

    b = DynamicBatcher(slow_serve, BatcherConfig(max_batch=64,
                                                 max_delay_s=0.05))
    try:
        prev = b.reconfigure(max_delay_s=0.001)
        assert prev.max_delay_s == pytest.approx(0.05)
        assert b.cfg.max_delay_s == pytest.approx(0.001)
        with pytest.raises(ValueError):
            b.reconfigure(num_dispatchers=4)
        assert b.queue_depth() == 0 and b.oldest_age_s() == 0.0
        r = b.submit(1, 100.0)
        done.set()
        r.wait(5.0)
    finally:
        done.set()
        b.close()


def test_router_live_retune():
    from repro.shard.router import ShardRouter
    r = ShardRouter(2, dispatch_rows=256, coalesce_delay_s=0.002)
    try:
        assert r.set_dispatch_rows(64) == 256
        assert r.dispatch_rows == 64
        assert all(l.dispatch_rows == 64 and l.max_drain_rows == 256
                   for l in r.lanes)
        assert r.set_coalesce_delay(0.0) == pytest.approx(0.002)
        assert all(l.coalesce_delay_s == 0.0 for l in r.lanes)
        with pytest.raises(ValueError):
            r.set_dispatch_rows(0)
    finally:
        r.close()


# -------------------------------------------------------------- closed loop
def test_closed_loop_flip_swap_zero_failures_and_commit():
    """The ISSUE 6 acceptance path: skewed measurements flip the
    naive/preagg decision; the Replanner rolls the new plan through
    build -> warm -> publish while a serving thread hammers the
    deployment — zero failed requests, zero non-OK statuses — and the
    post-swap health check commits."""
    eng = make_engine()
    eng.deploy("f", SQL)
    assert dict(eng.handle("f").plan.window_impl)["w"] == "preagg"
    serve(eng, "f", n_batches=6)            # pre-swap baseline latency

    # preagg measured 10x slower per element than scan -> naive wins
    cal = CostCalibrator(min_samples=4)
    for _ in range(8):
        cal.observe("scan", 100.0, 0.0001)
        cal.observe("preagg", 100.0, 0.0010)
    model = cal.fit(base=eng.cost_model)
    assert model.preagg_el == pytest.approx(10.0)

    stop = threading.Event()
    failures = []
    served = [0]

    def hammer():
        rng = np.random.default_rng(9)
        while not stop.is_set():
            rk = rng.integers(0, 8, 4)
            rt = np.sort(rng.uniform(1100, 1500, 4)).astype(np.float32)
            try:
                fr = eng.request("f", rk.tolist(), rt.tolist())
                if not np.all(np.asarray(fr.status) == STATUS_OK):
                    failures.append(f"bad status {fr.status}")
                served[0] += 1
            except Exception as e:          # noqa: BLE001
                failures.append(repr(e))

    t = threading.Thread(target=hammer)
    t.start()
    try:
        rp = Replanner(eng, "f", min_health_batches=4)
        rep = rp.maybe_replan(model)
        assert rep["action"] == "swapped"
        # keep serving across the swap before stopping the hammer
        deadline = time.monotonic() + 10.0
        while served[0] < 20 and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        stop.set()
        t.join(timeout=10.0)
    assert failures == []                   # zero failed requests
    assert served[0] >= 20

    live = eng.handle("f")
    assert dict(live.plan.window_impl)["w"] == "naive"   # decision flipped
    assert rp.state == Replanner.MONITORING
    serve(eng, "f", n_batches=6, seed=11)
    health = rp.check_health()
    assert health["action"] == "committed"
    assert rp.state == Replanner.IDLE
    json.dumps(rp.events)
    eng.close()


def test_closed_loop_auto_rollback_on_p99_regression():
    """When the swapped version's observed p99 regresses past the
    factor, the Replanner rolls back through Engine.rollback and
    restores the pre-swap cost model."""
    eng = make_engine()
    eng.deploy("f", SQL)
    serve(eng, "f", n_batches=6)
    live = eng.handle("f")
    v1 = live.version
    # healthy baseline: overwrite the reservoir with tight latencies
    live.metrics.latency_s.clear()
    for _ in range(32):
        live.metrics.observe_latency(0.002)

    model = CostModel(preagg_el=10.0)
    rp = Replanner(eng, "f", min_health_batches=8, regress_factor=1.5)
    rep = rp.maybe_replan(model)
    assert rep["action"] == "swapped"
    new = eng.handle("f")
    assert new.version != v1
    # the new plan is measured much slower post-swap
    for _ in range(16):
        new.metrics.observe_latency(0.050)
    health = rp.check_health()
    assert health["action"] == "rolled_back"
    assert eng.handle("f").version == v1               # old version live
    assert eng.cost_model == CostModel()               # model restored
    # next replan attempt with the same fitted model is allowed again
    assert rp.state == Replanner.IDLE
    eng.close()


def test_replan_no_change_keeps_model_without_swap():
    eng = make_engine()
    eng.deploy("f", SQL)
    v1 = eng.handle("f").version
    # mild recalibration that flips nothing
    model = CostModel(preagg_el=1.2)
    rp = Replanner(eng, "f")
    rep = rp.maybe_replan(model)
    assert rep["action"] == "no_change"
    assert eng.handle("f").version == v1
    assert eng.cost_model == model          # truer costs stay installed
    eng.close()


def test_control_plane_tick_and_snapshot():
    eng = make_engine()
    eng.deploy("f", SQL)
    plane = ControlPlane(eng, "f", rel_tol=0.2)
    serve(eng, "f", n_batches=6)
    r1 = plane.tick()
    serve(eng, "f", n_batches=6, seed=5)
    r2 = plane.tick()
    assert r2["tick"] == 1
    assert r2["observations_fed"] > 0        # measured time attributed
    assert r2["load"]["requests"] == 48
    snap = plane.snapshot()
    json.dumps(snap)                          # end-to-end serializable
    assert snap["deployment"] == "f"
    assert snap["telemetry"]["n_samples"] == 2
    eng.close()


def test_control_plane_background_loop():
    eng = make_engine()
    eng.deploy("f", SQL)
    plane = ControlPlane(eng, "f")
    plane.start(interval_s=0.02)
    try:
        serve(eng, "f", n_batches=4)
        deadline = time.monotonic() + 5.0
        while not plane.reports and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        plane.stop()
    assert plane.reports                      # it ticked on its own
    eng.close()


# ---------------------------------------------------- client latency signal
def test_batcher_client_latency_includes_queueing():
    """client_latency_percentile measures enqueue->completion, so a
    queue building in front of a fast serve shows up in it even though
    the serve-side latency stays flat."""
    from repro.serving.batcher import BatcherConfig, DynamicBatcher
    gate = threading.Event()

    def gated_serve(keys, ts, payloads):
        gate.wait(5.0)
        return {"x": np.zeros(len(keys), np.float32)}

    b = DynamicBatcher(gated_serve, BatcherConfig(max_batch=64,
                                                  max_delay_s=0.0))
    try:
        assert math.isnan(b.client_latency_percentile(99))
        rs = [b.submit(i, 100.0) for i in range(8)]
        time.sleep(0.05)                 # queueing time, serve blocked
        gate.set()
        for r in rs:
            r.wait(5.0)
        p99 = b.client_latency_percentile(99)
        assert math.isfinite(p99) and p99 >= 0.05
    finally:
        gate.set()
        b.close()


def test_plane_prefers_client_observed_p99():
    """With a batcher fronting the engine the knob controller must see
    the queueing-INCLUSIVE p99 — the serve-side p99 goes blind exactly
    when the queue builds."""
    from repro.serving.batcher import BatcherConfig, DynamicBatcher

    class _Srv:                          # duck-typed FeatureServer
        def __init__(self, batcher):
            self.batcher = batcher

    eng = make_engine()
    eng.deploy("f", SQL)

    def fserve(keys, ts, payloads):
        fr = eng.request("f", list(keys), list(ts))
        return dict(fr.columns)

    b = DynamicBatcher(fserve, BatcherConfig(max_batch=8,
                                             max_delay_s=0.001))
    plane = ControlPlane(eng, "f", server=_Srv(b))
    try:
        rs = [b.submit(k, 2000.0) for k in range(16)]
        for r in rs:
            r.wait(5.0)
        sample = plane.collector.sample()
        client_p99 = sample["batcher"]["client_p99_s"]
        assert math.isfinite(client_p99)
        # the series is exported for dashboards too
        assert "batcher.client_p99_s" in plane.collector.series
        obs = plane._load_observation(sample)
        assert obs.p99_s == pytest.approx(client_p99)
        # client p99 can only sit ABOVE the serve-side p99 it wraps
        serve_p99 = sample["deployments"]["f"]["snapshot"].get(
            "latency_p99_s", float("nan"))
        if math.isfinite(serve_p99):
            assert client_p99 >= serve_p99 * 0.5
    finally:
        b.close()
        eng.close()
