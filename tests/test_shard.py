"""Sharded serving runtime: routing invariants, sharded-vs-unsharded
bit-identical outputs (incl. LAST JOIN) on a disordered streamed load,
deadline shedding (whole-batch, never mixed), admission control, and
cross-shard deployment lifecycle (DESIGN.md §9)."""
import threading
import time

import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.core.results import (STATUS_OK, STATUS_SHED, FeatureFrame,
                                RequestContext)
from repro.featurestore.table import TableSchema
from repro.shard import (AdmissionConfig, ShardConfig, ShardedEngine,
                         shard_ids, shard_of)
from repro.shard.router import ShardRouter, SubBatch

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c,
AVG(amount) OVER w AS a
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""

SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))


def _events(n=600, n_keys=24, n_dim_keys=8, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n)
    ts = np.sort(rng.uniform(0, 1000.0, n)).astype(np.float32)
    rows = np.stack(
        [rng.normal(size=n),
         rng.integers(0, n_dim_keys, n).astype(np.float64)],
        -1).astype(np.float32)
    return keys, ts, rows


def _disorder(keys, ts, rows, lateness, seed=1):
    """Shuffle events within a bounded disorder window (repairable)."""
    rng = np.random.default_rng(seed)
    jitter = rng.uniform(0, 0.45 * lateness, len(ts))
    order = np.argsort(ts + jitter.astype(np.float32), kind="stable")
    return keys[order], ts[order], rows[order]


# ---------------------------------------------------------------------------
# routing invariants
# ---------------------------------------------------------------------------

def test_shard_of_is_pure_and_stable():
    for n in (1, 2, 4, 7):
        a = [shard_of(k, n) for k in range(200)]
        b = [shard_of(k, n) for k in range(200)]
        assert a == b
        assert set(a) <= set(range(n))
    karr = np.arange(200)
    assert np.array_equal(shard_ids(karr, 4),
                          np.asarray([shard_of(k, 4) for k in karr]))
    # non-integer keys route deterministically too
    assert shard_of("user-17", 4) == shard_of("user-17", 4)


def test_same_key_same_shard_across_publishes_and_redeploys():
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=4))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)

    def serve_and_snapshot_counts(key):
        before = [h.metrics.requests for h in se.handle("q").handles]
        se.request("q", [key], [2000.0])
        after = [h.metrics.requests for h in se.handle("q").handles]
        hits = [i for i, (b, a) in enumerate(zip(before, after)) if a > b]
        assert len(hits) == 1
        return hits[0]

    owner = {k: serve_and_snapshot_counts(int(k)) for k in range(8)}
    for k, s in owner.items():
        assert s == se.shard_of(k)      # ring ownership, not modulo
    # more publishes (ingest) + a redeploy must not move any key
    se.insert("events", keys[:50].tolist(),
              (ts[:50] + 5000.0).tolist(), rows[:50])
    se.deploy("q", SQL.replace("10 PRECEDING", "5 PRECEDING"))
    for k in range(8):
        assert serve_and_snapshot_counts(int(k)) == owner[k]
    se.close()


def test_string_key_routing_scalar_matches_vectorized():
    """Non-integer keys must route identically through the scalar path
    (ShardedPipeline.push) and the vectorized path (scatter/insert) —
    numpy scalar reprs differ from Python value reprs, so the hash has
    to normalize before hashing."""
    ks = [f"user-{i}" for i in range(64)] + [1.5, 2.25, -3.75]
    arr = np.asarray(ks, dtype=object)
    sarr = np.asarray([f"user-{i}" for i in range(64)])   # '<U' dtype
    for n in (2, 4, 7):
        scalar = [shard_of(k, n) for k in ks]
        assert list(shard_ids(arr, n)) == scalar
        # numpy scalar elements (what iterating an ndarray yields)
        assert [shard_of(k, n) for k in arr] == scalar
        assert list(shard_ids(sarr, n)) == scalar[:64]


def test_query_offline_with_empty_shards():
    """Hash skew can leave shards without a single key; offline
    materialisation must skip them, not crash."""
    se = ShardedEngine(ShardConfig(n_shards=4))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    # two keys -> at most two occupied shards (at least two are empty)
    keys = np.asarray([0, 4] * 40)
    ts = np.sort(np.random.default_rng(0).uniform(0, 100, 80))
    rows = np.random.default_rng(1).normal(size=(80, 2)).astype(np.float32)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    res = se.query_offline("q")
    assert len(res["__key"]) == 80
    assert set(res["__key"].tolist()) == {0, 4}
    assert len(res["__version_vector"]) == 4
    occupied = {se.shard_of(0), se.shard_of(4)}
    assert set(res["__shard"].tolist()) == occupied
    se.close()


# ---------------------------------------------------------------------------
# sharded vs unsharded: bit-identical on a disordered streamed load
# ---------------------------------------------------------------------------

def _build_pair(n_shards=3, lateness=30.0, with_join=False):
    keys, ts, rows = _events()
    dkeys, dts, drows = _disorder(keys, ts, rows, lateness)

    ref = Engine(OptFlags())
    se = ShardedEngine(ShardConfig(n_shards=n_shards))
    for eng in (ref, se):
        eng.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    if with_join:
        dim_schema = TableSchema("dim", key_col="mkey", ts_col="dts",
                                 value_cols=("risk", "tier"))
        ref.create_table(dim_schema, max_keys=16, capacity=16,
                         bucket_size=8)
        se.create_table(dim_schema, max_keys=16, capacity=16,
                        bucket_size=8, replicate=True)
        for t0 in (100.0, 600.0):
            dk = list(range(8))
            drow = np.stack([np.arange(8) + t0, np.arange(8) * 0.5],
                            -1).astype(np.float32)
            ref.insert("dim", dk, [t0] * 8, drow)
            se.insert("dim", dk, [t0] * 8, drow)
    rpipe = ref.attach_stream("events", lateness=lateness,
                              flush_interval_s=0.001)
    spipe = se.attach_stream("events", lateness=lateness,
                             flush_interval_s=0.001)
    for i in range(len(dkeys)):
        rpipe.push(int(dkeys[i]), float(dts[i]), drows[i])
        spipe.push(int(dkeys[i]), float(dts[i]), drows[i])
    rpipe.flush()
    spipe.flush()
    return ref, se, (keys, ts, rows)


def test_sharded_bit_identical_to_unsharded_streamed():
    ref, se, (keys, ts, rows) = _build_pair()
    ref.deploy("q", SQL)
    se.deploy("q", SQL)
    rng = np.random.default_rng(7)
    for b in range(3):
        rk = rng.integers(0, 24, 16).tolist()
        rt = np.full(16, 2000.0 + b, np.float32).tolist()
        a = ref.request("q", rk, rt)
        s = se.request("q", rk, rt)
        assert isinstance(s, FeatureFrame)
        assert s.version_vector is not None
        assert len(s.version_vector) == 3
        for n in a:
            assert np.array_equal(np.asarray(a[n]), np.asarray(s[n])), n
        assert np.array_equal(a.status, s.status)
    ref.close()
    se.close()


def test_sharded_last_join_bit_identical_and_offline_parity():
    from repro.core import dsl
    ref, se, (keys, ts, rows) = _build_pair(with_join=True)
    qb = (dsl.QueryBuilder("events")
          .window("w", partition_by="user", order_by="ts", rows=10)
          .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                  risk=dsl.tbl("dim").risk)
          .last_join("dim", on="mkey", order_by="dts"))
    ref.deploy("jq", qb)
    qb2 = (dsl.QueryBuilder("events")
           .window("w", partition_by="user", order_by="ts", rows=10)
           .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                   risk=dsl.tbl("dim").risk)
           .last_join("dim", on="mkey", order_by="dts"))
    se.deploy("jq", qb2)
    rng = np.random.default_rng(9)
    idx = rng.integers(0, len(keys), 16)
    rk = keys[idx].tolist()
    rt = np.full(16, 2000.0, np.float32).tolist()
    rr = rows[idx]
    a = ref.request("jq", rk, rt, rows=rr)
    s = se.request("jq", rk, rt, rows=rr)
    for n in a:
        assert np.array_equal(np.asarray(a[n]), np.asarray(s[n])), n

    # cross-shard staleness rollups stay sane: rates are recomputed from
    # summed counters (never summed across shards)
    st = se.handle("jq").join_staleness()["dim"]
    assert 0.0 < st["match_rate"] <= 1.0
    dec = se.latency_decomposition()
    assert 0.0 < dec["join_match_rate"] <= 1.0
    assert dec["join_probes"] >= 16

    # offline: same rows, same joined features, independent of shard order
    oa = ref.query_offline("jq")
    ob = se.query_offline("jq")
    inv = {i: k for k, i in ref.tables["events"].key_to_idx.items()}
    ka = np.asarray([inv[int(i)] for i in oa["__key"]])
    ia = np.lexsort((oa["__ts"], ka))
    ib = np.lexsort((ob["__ts"], ob["__key"]))
    assert np.array_equal(ka[ia], ob["__key"][ib])
    for n in ("s", "risk"):
        assert np.array_equal(oa[n][ia], ob[n][ib]), n
    assert len(ob["__version_vector"]) == 3
    ref.close()
    se.close()


def test_join_on_partitioned_right_table_rejected():
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.create_table(TableSchema("dim", key_col="mkey", ts_col="dts",
                                value_cols=("risk",)),
                    max_keys=16, capacity=16, bucket_size=8)  # partitioned!
    from repro.core import dsl
    qb = (dsl.QueryBuilder("events")
          .window("w", partition_by="user", order_by="ts", rows=5)
          .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                  risk=dsl.tbl("dim").risk)
          .last_join("dim", on="mkey", order_by="dts"))
    with pytest.raises(ValueError, match="replicate=True"):
        se.deploy("jq", qb)
    se.close()


# ---------------------------------------------------------------------------
# deadline shedding + admission control
# ---------------------------------------------------------------------------

def test_shed_on_deadline_whole_batch_error_status():
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    # expired before admission: whole batch shed, no exception
    ctx = RequestContext(deadline=time.monotonic() - 1.0)
    out = se.request("q", list(range(8)), [2000.0] * 8, ctx=ctx)
    assert out.status.shape == (8,)
    assert (out.status == STATUS_SHED).all()          # never a mixed batch
    assert out.n_shed == 8 and not out.all_ok
    assert set(out.keys()) == set(se.handle("q").phys.feature_names)
    assert all(np.asarray(out[n]).shape == (8,) for n in out)
    # a healthy request afterwards is untouched
    ok = se.request("q", list(range(8)), [2001.0] * 8)
    assert (ok.status == STATUS_OK).all()
    m = se.handle("q").metrics
    assert m.shed_batches == 1 and m.shed_requests == 8
    assert se.resources.metrics()["shed_deadline"] >= 1
    se.close()


def test_router_sheds_expired_subbatch_at_dequeue():
    """A sub-batch whose deadline passed while QUEUED is dropped before
    compute (shed=True), and the gather reports whole-batch shed."""
    router = ShardRouter(1, dispatch_rows=8)

    class _Handle:
        class table:
            class schema:
                value_cols = ("amount",)

        def request(self, k, t, r, ctx=None):      # pragma: no cover
            raise AssertionError("shed sub-batch must never be computed")

    item = SubBatch(_Handle(), np.arange(4), np.zeros(4, np.float32),
                    None, ctx=RequestContext(deadline=time.monotonic() - 1))
    router.submit(0, item)
    assert item.done.wait(5.0)
    assert item.shed and item.error is None
    cols, status, _, any_shed = router.gather(
        [(np.arange(4), item)], 4)
    assert any_shed and cols is None
    router.close()


def test_admission_control_inflight_backpressure():
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(
        n_shards=2,
        admission=AdmissionConfig(max_inflight=1, admit_timeout_s=0.05)))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    se.request("q", [1, 2], [2000.0] * 2)       # warm
    # hold the only slot, then a second admit must reject with
    # backpressure after the admit timeout
    adm = se.resources.admit("q")
    assert not adm.shed
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="admission control"):
        se.request("q", [1, 2], [2001.0] * 2)
    assert time.monotonic() - t0 >= 0.04
    adm.release()
    out = se.request("q", [1, 2], [2002.0] * 2)  # slot free again
    assert out.all_ok
    stats = se.resources.metrics()
    assert stats["rejected_inflight"] == 1
    # ...but an expired-deadline wait sheds instead of raising
    ctx = RequestContext.with_timeout(0.02)
    adm2 = se.resources.admit("q")
    shed = se.request("q", [1, 2], [2003.0] * 2, ctx=ctx)
    adm2.release()
    assert (shed.status == STATUS_SHED).all()
    se.close()


# ---------------------------------------------------------------------------
# cross-shard deployment lifecycle
# ---------------------------------------------------------------------------

def test_sharded_hotswap_canary_promote_rollback():
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    v1 = se.deploy("q", SQL)
    rk, rt = list(range(8)), [2000.0] * 8
    base = se.request("q", rk, rt)
    assert base.version == 1

    # canary=1.0 routes every batch to the candidate, incumbent compares
    se.deploy("q", SQL.replace("10 PRECEDING", "5 PRECEDING"), canary=1.0)
    out = se.request("q", rk, rt)
    assert out.version == 2                    # candidate served
    assert se.handle("q").version == 1         # incumbent still live
    cand = se.handle("q", version=2)
    assert cand.metrics.canary_batches == 1
    assert cand.metrics.canary_max_abs_diff >= 0.0
    se.promote("q")
    assert se.handle("q").version == 2
    # per-shard inner engines published atomically alongside
    for h in se.handle("q").handles:
        assert h.live

    se.rollback("q")
    assert se.handle("q").version == 1
    after = se.request("q", rk, rt)
    assert np.array_equal(after["s"], base["s"])
    # version pinning still works across the sharded registry
    pinned = se.request("q", rk, rt, ctx=RequestContext(version_pin=1))
    assert pinned.version == 1
    se.close()


def test_sharded_feature_server_end_to_end():
    from repro.serving.server import FeatureServer, ServerConfig
    from repro.serving.batcher import BatcherConfig
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.attach_stream("events", lateness=5.0, flush_interval_s=0.001)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    srv = FeatureServer(se, "q",
                        ServerConfig(BatcherConfig(max_batch=8,
                                                   max_delay_s=0.005)))
    outs = {}

    def client(i):
        outs[i] = srv.request(i % 16, 2000.0 + i)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(outs) == 16
    for o in outs.values():
        assert np.isfinite(o["s"])
    # the server's write path routes through the sharded pipeline facade
    assert srv.ingest(3, 3000.0, np.asarray([1.0, 0.0], np.float32))
    srv.close()
    se.close()


# ---------------------------------------------------------------------------
# elastic resharding (consistent-hash ring) + transactional ingest
# ---------------------------------------------------------------------------

def test_elastic_reshard_under_live_traffic():
    """Grow then shrink the shard set while a client thread hammers the
    deployment: every response is either bit-identical to the unsharded
    reference or an explicit shed — never wrong, never an exception —
    and parity holds before/during/after both reshards."""
    keys, ts, rows = _events()
    ref = Engine(OptFlags())
    ref.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    ref.insert("events", keys.tolist(), ts.tolist(), rows)
    ref.deploy("q", SQL)
    rk = list(range(24))
    rt = [2000.0] * 24
    want = ref.request("q", rk, rt)

    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)

    stop = threading.Event()
    errors = []
    checked = [0]

    def hammer():
        while not stop.is_set():
            try:
                got = se.request("q", rk, rt)
            except Exception as e:      # noqa: BLE001 — the test asserts
                errors.append(e)
                return
            if (got.status == STATUS_SHED).any():
                continue
            for n in want:
                if not np.array_equal(np.asarray(want[n]),
                                      np.asarray(got[n])):
                    errors.append(AssertionError(
                        f"column {n} diverged during reshard"))
                    return
            checked[0] += 1

    t = threading.Thread(target=hammer)
    t.start()
    try:
        s_new = se.add_shard()          # 2 -> 3 under live traffic
        assert se.n_shards == 3
        moved_in = se._routing.shard_counts().get(s_new, 0)
        assert moved_in > 0             # the new shard owns real ranges
        time.sleep(0.1)
        moved = se.remove_shard(0)      # 3 -> 2 under live traffic
        assert se.n_shards == 2
        assert moved >= 0
        time.sleep(0.1)
    finally:
        stop.set()
        t.join(timeout=30)
    assert not errors, errors[:1]
    assert checked[0] > 0               # traffic actually flowed

    got = se.request("q", rk, rt)
    assert np.array_equal(want.status, got.status)
    for n in want:
        assert np.array_equal(np.asarray(want[n]), np.asarray(got[n])), n
    # offline parity too: stale migrated copies must not surface
    oa = ref.query_offline("q")
    ob = se.query_offline("q")
    inv = {i: k for k, i in ref.tables["events"].key_to_idx.items()}
    ka = np.asarray([inv[int(i)] for i in oa["__key"]])
    ia = np.lexsort((oa["__ts"], ka))
    ib = np.lexsort((ob["__ts"], ob["__key"]))
    assert np.array_equal(ka[ia], ob["__key"][ib])
    for n in ("s", "c", "a"):
        assert np.array_equal(oa[n][ia], ob[n][ib]), n
    assert 0 not in set(ob["__shard"].tolist())   # retired slot is gone
    ref.close()
    se.close()


def test_modulo_partitioner_cannot_reshard():
    se = ShardedEngine(ShardConfig(n_shards=2, partitioner="modulo"))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    for k in range(32):
        assert se.shard_of(k) == shard_of(k, 2)
    with pytest.raises(RuntimeError, match="cannot reshard"):
        se.add_shard()
    with pytest.raises(RuntimeError, match="cannot reshard"):
        se.remove_shard(0)
    se.close()


def test_cross_shard_insert_all_or_nothing():
    """Regression: before the 2PC path, a multi-shard insert into a
    stream-attached table applied shard 0's slice even when shard 1's
    was rejected as unrepairably late."""
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    pipe = se.attach_stream("events", lateness=1.0)
    ka = next(k for k in range(100) if se.shard_of(k) == 0)
    kb = next(k for k in range(100) if se.shard_of(k) == 1)
    se.insert("events", [ka], [100.0], np.ones((1, 2), np.float32))
    pipe.flush()
    se.deploy("q", SQL)
    with pytest.raises(ValueError, match="rejected atomically"):
        se.insert("events", [ka, kb], [10.0, 200.0],
                  np.ones((2, 2), np.float32))
    pipe.flush()
    fr = se.request("q", [kb], [500.0])
    assert fr.status.tolist() != [STATUS_OK]      # nothing staged for kb
    se.insert("events", [ka, kb], [300.0, 300.0],
              np.ones((2, 2), np.float32))
    pipe.flush()
    fr = se.request("q", [ka, kb], [500.0, 500.0])
    assert fr.status.tolist() == [STATUS_OK, STATUS_OK]
    assert fr.columns["c"].tolist() == [2.0, 1.0]
    se.close()


def test_router_shutdown_drains_inflight_gathers():
    """Regression: ShardRouter.close() used to stop lanes and close
    queues with sub-batches still queued — an in-flight gather could
    race the teardown. shutdown(drain=True) must complete every queued
    sub-batch first; requests submitted AFTER shutdown fail fast."""
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=3, coalesce_delay_s=0.02))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    results = []
    refused = []

    def client(i):
        try:
            results.append(se.request("q", [i % 16], [2000.0]))
        except RuntimeError as e:   # submitted after accepting flipped
            refused.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(12)]
    for th in threads:
        th.start()
    # let the submits land; the coalesce delay keeps the sub-batches
    # QUEUED while we tear down — exactly the old race window
    time.sleep(0.01)
    se.close()
    for th in threads:
        th.join(timeout=30)
        assert not th.is_alive(), "request hung across shutdown"
    # every request either completed fully (drained) or failed fast at
    # submit — no partial results, no hangs, no raw lane errors
    assert len(results) + len(refused) == 12
    assert results, "no request made it in before close()"
    for fr in results:
        assert (fr.status == STATUS_OK).all()
    for e in refused:
        assert "closed" in str(e)
    with pytest.raises(RuntimeError, match="closed"):
        se.router.scatter(se.handle("q").handles, np.asarray([1]),
                          np.asarray([2000.0], np.float32), None)
