"""Observability tier (DESIGN.md §13): distributed tracing across every
tier (server -> batcher -> admission -> router lane -> worker engine ->
kernel spans, including re-based adoption across the process-backend
transport), EXPLAIN ANALYZE operator attribution, the decomposition
identity tripwire, and the unified metrics registry / exporters.

Process-backend tests spawn subprocess workers (jax import ~seconds);
they keep shard counts at 2 and reuse engines across asserts. The CI
``obs`` leg runs this file under both REPRO_SHARD_BACKEND values and
once more with a seeded REPRO_FAULT_PLAN (ShardConfig resolves the env
automatically), so the trace/profile paths are exercised over a lossy
transport too.
"""
import json
import math
import os
import signal
import time

import numpy as np
import pytest

from repro.core.engine import Engine, EngineStats
from repro.core.optimizer import OptFlags
from repro.core.results import (STATUS_OK, STATUS_UNKNOWN_KEY,
                                RequestContext)
from repro.featurestore.table import TableSchema
from repro.obs.export import MetricsRegistry, registry_from_engine
from repro.obs.trace import _B32, Tracer, new_trace_id
from repro.serving.batcher import BatcherConfig
from repro.serving.server import FeatureServer, ServerConfig
from repro.shard import ShardConfig, ShardedEngine

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""
SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))


def _events(n=300, n_keys=8, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n)
    ts = np.sort(rng.uniform(0, 1000.0, n)).astype(np.float32)
    rows = np.stack(
        [rng.normal(size=n),
         rng.integers(0, 4, n).astype(np.float64)], -1).astype(np.float32)
    return keys, ts, rows


def _engine(sample=1.0):
    keys, ts, rows = _events()
    eng = Engine(OptFlags())
    eng.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy("q", SQL)
    eng.tracer.set_sample_rate(sample)
    return eng


def _names(tracer, trace_id):
    return {s.name for s in tracer.trace(trace_id)}


# =============================================================== trace ids
def test_new_trace_id_ulid_format_unique_and_sortable():
    ids = [new_trace_id() for _ in range(2000)]
    assert len(set(ids)) == len(ids)
    for t in ids[:50]:
        assert len(t) == 26 and all(c in _B32 for c in t)
    a = new_trace_id()
    time.sleep(0.003)        # > 1 ms: the 48-bit ms prefix must advance
    b = new_trace_id()
    assert a < b             # lexical order == creation order


def test_server_autogenerates_trace_id_when_absent():
    """Satellite bugfix: a request without a ctx (or with a trace-less
    ctx) must still come back traceable — the id is minted at the
    serving edge and survives the batcher hop."""
    eng = _engine(sample=1.0)
    with FeatureServer(eng, "q", ServerConfig(
            batcher=BatcherConfig(max_batch=4, max_delay_s=0.001))) as srv:
        res = srv.request(1, 2000.0)
        assert res.trace_id is not None
        assert len(res.trace_id) == 26
        assert all(c in _B32 for c in res.trace_id)
        # the minted id is the one the spans were recorded under
        assert "server.request" in _names(eng.tracer, res.trace_id)
        # a caller-provided id is preserved verbatim, never replaced
        tid = new_trace_id()
        res2 = srv.request(2, 2000.0, ctx=RequestContext(trace_id=tid))
        assert res2.trace_id == tid
        # a trace-less ctx (deadline only) also gets an id
        res3 = srv.request(3, 2000.0, ctx=RequestContext())
        assert res3.trace_id is not None and res3.trace_id != tid


# ================================================================= tracer
def test_tracer_sampling_deterministic_across_instances():
    ids = [new_trace_id() for _ in range(256)]
    a, b = Tracer(sample_rate=0.5), Tracer(sample_rate=0.5)
    assert [a.sampled(t) for t in ids] == [b.sampled(t) for t in ids]
    kept = sum(a.sampled(t) for t in ids)
    assert 0 < kept < len(ids)              # rate actually partitions
    z = Tracer(sample_rate=0.0)
    assert z.start("x", ids[0]) is None     # zero-overhead fast path
    assert z.record("x", ids[0], None, 0.0, 1.0) is None
    full = Tracer(sample_rate=1.0)
    assert all(full.sampled(t) for t in ids)
    assert not full.sampled(None)


def test_tracer_bounded_storage_lru_and_span_cap():
    tr = Tracer(sample_rate=1.0, max_traces=2, max_spans_per_trace=3)
    tids = [new_trace_id() for _ in range(3)]
    for tid in tids:
        s = tr.start("root", tid)
        tr.finish(s)
    assert tr.counters["traces_evicted"] == 1
    assert tr.trace(tids[0]) == []          # oldest evicted
    assert tr.trace(tids[2])
    # per-trace span cap: 4th span of one trace is dropped, not stored
    tid = tids[2]
    root = tr.trace(tid)[0]
    for _ in range(3):
        tr.finish(tr.start("child", tid, parent_id=root.span_id))
    assert len(tr.trace(tid)) == 3
    assert tr.counters["spans_dropped"] >= 1


def test_tracer_adopt_rebases_and_dedups():
    worker = Tracer(sample_rate=1.0)
    tid = new_trace_id()
    s = worker.start("engine.serve", tid, parent_id="p-1")
    time.sleep(0.001)
    worker.finish(s)
    export = worker.export_trace(tid)
    client = Tracer(sample_rate=1.0)
    assert client.adopt(export, rebase=100.0) == 1
    got = client.trace(tid)[0]
    assert got.start == pytest.approx(s.start + 100.0)
    assert got.duration_s == pytest.approx(s.duration_s)
    # re-adoption (the at-least-once transport's dup path) is a no-op
    before = len(client.trace(tid))
    assert client.adopt(export, rebase=100.0) == 0
    assert client.counters["spans_deduped"] >= 1
    assert len(client.trace(tid)) == before


def test_tracer_tree_attaches_orphans_under_root():
    tr = Tracer(sample_rate=1.0)
    tid = new_trace_id()
    root = tr.start("server.request", tid)
    child = tr.start("engine.serve", tid, parent_id=root.span_id)
    orphan = tr.start("lane.execute", tid, parent_id="never-recorded")
    stray_root = tr.start("admission", tid)     # parentless sibling
    for s in (child, orphan, stray_root, root):
        tr.finish(s)
    tree = tr.tree(tid)
    assert tree["name"] == "server.request"
    names = {n["name"] for n in Tracer.walk(tree)}
    assert names == {"server.request", "engine.serve", "lane.execute",
                     "admission"}       # nothing silently dropped


def test_tracer_slow_query_log_captures_p99_outliers():
    tr = Tracer(sample_rate=1.0, slow_min_samples=5, slow_log_size=4)
    for i in range(20):
        tid = new_trace_id()
        s = tr.start("server.request", tid)
        s.start = time.perf_counter() - 1e-4    # ~0.1 ms roots
        tr.finish(s)
    tid = new_trace_id()
    s = tr.start("server.request", tid)
    s.start = time.perf_counter() - 0.5         # one 500 ms outlier
    tr.finish(s)
    slow = tr.slow_queries()
    assert slow and slow[-1]["trace_id"] == tid
    assert slow[-1]["duration_s"] > 0.4
    assert tr.counters["slow_queries"] >= 1
    assert any(sp["name"] == "server.request"
               for sp in slow[-1]["spans"])


# ================================================ decomposition identity
def test_engine_stats_stage_tripwire():
    """Every ``*_s`` timing field must be a declared serve STAGE,
    serve_s itself, or parse_s (deploy-time). Adding a new stage without
    deciding whether it is inside the serve wall fails HERE, not in a
    drifted dashboard."""
    timing = {f for f in EngineStats._FIELDS if f.endswith("_s")}
    assert timing == set(EngineStats.STAGES) | {"serve_s", "parse_s"}


def test_latency_decomposition_stages_sum_to_serve():
    """Satellite bugfix: over any serve-only interval the measured
    stages sum to the serve wall (plan accrues OUTSIDE serves too — at
    deploy/warm — so the identity is on interval deltas, not
    lifetime totals)."""
    eng = _engine(sample=0.0)
    eng.request("q", [1], [2000.0])         # pay first-compile outside
    before = eng.stats.snapshot()
    for i in range(6):
        fr = eng.request("q", list(range(i + 1)), [2000.0] * (i + 1))
        assert (fr.status == STATUS_OK).all()
    d = eng.stats.delta(before)
    assert d["serve_s"] > 0
    stage_sum = sum(d[f] for f in EngineStats.STAGES)
    assert stage_sum == pytest.approx(d["serve_s"], rel=0.05, abs=1e-4)
    # and the public decomposition exposes every stage + the total
    decomp = eng.latency_decomposition()
    for f in EngineStats.STAGES + ("serve_s",):
        assert f in decomp, f


# ======================================================== EXPLAIN ANALYZE
def test_explain_analyze_attribution_matches_measured():
    eng = _engine(sample=0.0)
    before = eng.stats.snapshot()
    for _ in range(4):
        eng.request("q", list(range(8)), [2000.0] * 8)
    d = eng.stats.delta(before)
    prof = eng.profiler.snapshot("q")
    # attributed operator seconds sum to the measured exec clock exactly
    op_total = sum(r["seconds"] for r in prof["ops"].values())
    assert op_total == pytest.approx(prof["exec_s"], rel=1e-6)
    # the profiler clocks the same serves the stats counters saw
    assert prof["exec_s"] == pytest.approx(d["exec_s"], rel=1e-6)
    assert prof["requests"] == d["n_requests"]
    # acceptance: attributed total within 10% of the measured serve wall
    attributed = op_total + prof["host_s"] + prof["plan_s"]
    assert attributed == pytest.approx(prof["serve_s"], rel=0.10)
    txt = eng.explain_analyze("q")
    assert "EXPLAIN ANALYZE deployment 'q'" in txt
    assert "% of exec" in txt and "host/keydir" in txt
    # the textual attribution footer agrees (100% by construction)
    assert "(100.0%)" in txt


def test_explain_analyze_resolves_sql_text():
    eng = _engine(sample=0.0)
    eng.request("q", [1, 2], [2000.0] * 2)
    by_name = eng.explain_analyze("q")
    by_sql = eng.explain_analyze("EXPLAIN ANALYZE " + SQL)
    assert by_sql == by_name
    with pytest.raises(KeyError, match="no live deployment"):
        eng.explain_analyze(
            "EXPLAIN ANALYZE " + SQL.replace("10 PRECEDING",
                                             "7 PRECEDING"))


def test_profiler_observations_feed_calibrator_kinds():
    eng = _engine(sample=0.0)
    eng.request("q", list(range(4)), [2000.0] * 4)
    obs = eng.drain_profile_observations("q")
    kinds = {o["kind"] for o in obs}
    assert kinds and kinds <= {"scan", "preagg", "join"}
    for o in obs:
        assert o["seconds"] >= 0 and o["elements"] > 0
    # drained: the interval accumulator popped
    assert eng.drain_profile_observations("q") == []


# ============================================================ trace trees
def test_single_engine_trace_has_kernel_children():
    eng = _engine(sample=1.0)
    tid = new_trace_id()
    fr = eng.request("q", list(range(4)), [2000.0] * 4,
                     ctx=RequestContext(trace_id=tid))
    assert (fr.status == STATUS_OK).all()
    spans = eng.tracer.trace(tid)
    names = {s.name for s in spans}
    assert "engine.serve" in names
    kernels = [s for s in spans if s.name.startswith("kernel.")]
    assert kernels
    serve = next(s for s in spans if s.name == "engine.serve")
    for k in kernels:
        assert k.parent_id == serve.span_id
        assert k.start >= serve.start - 1e-6
        assert k.end <= serve.end + 1e-6
    # attributed kernel spans tile the measured exec window
    kernel_total = sum(k.duration_s for k in kernels)
    assert kernel_total <= serve.duration_s + 1e-6


def test_sharded_trace_tree_end_to_end():
    """Acceptance: one request through a FeatureServer over a 2-shard
    engine (backend from REPRO_SHARD_BACKEND — the CI obs leg runs both)
    yields ONE reassembled tree: client admission -> batcher -> router
    lane -> worker serve -> kernel launches."""
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    se.tracer.set_sample_rate(1.0)
    try:
        with FeatureServer(se, "q", ServerConfig(
                batcher=BatcherConfig(max_batch=4,
                                      max_delay_s=0.001))) as srv:
            srv.request(0, 2000.0)          # absorb any cold compiles
            res = srv.request(1, 2000.0)
            assert res.trace_id is not None
        tree = se.tracer.tree(res.trace_id)
        assert tree is not None and tree["name"] == "server.request"
        nodes = se.tracer.walk(tree)
        names = {n["name"] for n in nodes}
        for tier in ("server.request", "batch.queue_wait", "admission",
                     "router.scatter_gather", "lane.execute",
                     "engine.serve"):
            assert tier in names, (tier, sorted(names))
        assert any(n["name"].startswith("kernel.") for n in nodes)
        # worker serve nests inside the lane's window — on the process
        # backend this only holds because adoption re-based the worker's
        # clock onto the client's
        lanes = [n for n in nodes if n["name"] == "lane.execute"]
        serves = [n for n in nodes if n["name"] == "engine.serve"]
        for sv in serves:
            host = [ln for ln in lanes
                    if ln["start"] - 1e-3 <= sv["start"]
                    and sv["start"] + sv["duration_s"]
                    <= ln["start"] + ln["duration_s"] + 1e-3]
            assert host, "engine.serve not nested in any lane window"
        # every span id is unique (adoption dedup, no double-records)
        ids = [n["span_id"] for n in nodes]
        assert len(ids) == len(set(ids))
        # EXPLAIN ANALYZE merges per-shard profiles over the same path
        txt = se.explain_analyze("q")
        assert "EXPLAIN ANALYZE deployment 'q'" in txt
        assert "% of exec" in txt
    finally:
        se.close()


def test_proc_trace_survives_worker_respawn():
    """Satellite bugfix: trace ids survive the sharded gather and a
    worker respawn — the respawned worker's tracer re-arms (full
    worker-side sampling, client-side decision) and its spans adopt into
    the same client tracer."""
    keys, ts, rows = _events(n=200, n_keys=8)
    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    se.tracer.set_sample_rate(1.0)
    try:
        rk, rt = list(range(8)), [2000.0] * 8
        tid = new_trace_id()
        fr = se.request("q", rk, rt, ctx=RequestContext(trace_id=tid))
        assert (fr.status == STATUS_OK).all()
        assert fr.trace_id == tid           # survives the gather
        assert "engine.serve" in _names(se.tracer, tid)
        assert se.tracer.counters["spans_adopted"] > 0

        os.kill(se.shards[1].proc.pid, signal.SIGKILL)
        time.sleep(0.05)
        deadline = time.time() + 90
        while time.time() < deadline:
            fr = se.request("q", rk, rt)
            st = set(fr.status.tolist())
            if st <= {STATUS_OK, STATUS_UNKNOWN_KEY}:
                break
            time.sleep(0.1)
        assert se.worker_restarts == 1

        tid2 = new_trace_id()
        fr2 = se.request("q", rk, rt, ctx=RequestContext(trace_id=tid2))
        assert fr2.trace_id == tid2
        names = _names(se.tracer, tid2)
        assert "engine.serve" in names      # respawned worker exports
        spans = se.tracer.trace(tid2)
        ids = [s.span_id for s in spans]
        assert len(ids) == len(set(ids))
    finally:
        se.close()


# ======================================================= unified export
def test_registry_prometheus_golden():
    reg = MetricsRegistry(prefix="repro")
    reg.register("g", lambda: {
        "a": 3, "b": 2.5, "fraud/requests": 7,
        "nan_gauge": float("nan"), "label": "text", "flag": True})
    text = reg.render_prometheus()
    lines = text.strip().split("\n")
    assert lines == [
        "# HELP repro_g_a g a",
        "# TYPE repro_g_a gauge",
        "repro_g_a 3",
        "# HELP repro_g_b g b",
        "# TYPE repro_g_b gauge",
        "repro_g_b 2.5",
        "# HELP repro_g_requests g requests",
        '# TYPE repro_g_requests gauge',
        'repro_g_requests{item="fraud"} 7',
    ]


def test_prometheus_label_escaping():
    """Backslash, double-quote and newline in a label value render with
    the text-format escapes — a raw quote would corrupt the exposition."""
    reg = MetricsRegistry(prefix="repro")
    reg.register("g", lambda: {'a\\b"c\nd/x': 1})
    line = reg.render_prometheus().strip().split("\n")[-1]
    assert line == 'repro_g_x{item="a\\\\b\\"c\\nd"} 1'


def test_prometheus_sketch_renders_native_histogram():
    """A ``*_sketch`` dict value becomes a cumulative histogram family:
    ``_bucket`` series with ``le`` bounds, ``le="+Inf"``, ``_sum`` and
    ``_count`` — and the cumulative counts are monotone and total."""
    from repro.obs.sketch import QuantileSketch
    sk = QuantileSketch()
    vals = [0.001, 0.01, 0.01, 0.1, 1.0, 10.0]
    sk.observe_many(vals)
    reg = MetricsRegistry(prefix="repro")
    reg.register("g", lambda: {"lat/lat_sketch": sk.to_dict()})
    text = reg.render_prometheus()
    lines = text.strip().split("\n")
    assert "# TYPE repro_g_lat_sketch histogram" in lines
    buckets = [ln for ln in lines if "_bucket{" in ln]
    assert buckets[-1] == \
        f'repro_g_lat_sketch_bucket{{item="lat",le="+Inf"}} {len(vals)}'
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert cums == sorted(cums)             # cumulative = monotone
    ubs = [float(ln.split('le="')[1].split('"')[0])
           for ln in buckets[:-1]]
    assert ubs == sorted(ubs)               # ascending bounds
    assert f'repro_g_lat_sketch_count{{item="lat"}} {len(vals)}' in lines
    sum_line = [ln for ln in lines if "_sum{" in ln][0]
    assert float(sum_line.rsplit(" ", 1)[1]) == \
        pytest.approx(sum(vals), rel=1e-9)


def test_registry_jsonl_roundtrip_and_error_isolation():
    reg = MetricsRegistry()
    reg.register("ok", lambda: {"x": 1, "nan": float("nan")})

    def boom():
        raise RuntimeError("surface torn down")
    reg.register("bad", boom)
    out = reg.collect()
    assert out["ok"] == {"x": 1} or math.isnan(out["ok"]["nan"])
    assert out["bad"] == {}                 # exception isolated
    line = reg.render_jsonl(now=123.0)
    doc = json.loads(line)
    assert doc["t"] == 123.0
    assert doc["ok"]["x"] == 1
    assert math.isnan(doc["ok"]["nan"])     # NaN kept in JSONL
    assert doc["bad"] == {}
    # prometheus render survives the raising collector too
    assert "repro_ok_x 1" in reg.render_prometheus()


def test_registry_from_engine_groups_and_labels():
    eng = _engine(sample=1.0)
    tid = new_trace_id()
    eng.request("q", [1, 2], [2000.0] * 2,
                ctx=RequestContext(trace_id=tid))
    reg = registry_from_engine(eng)
    groups = reg.groups()
    for g in ("engine", "cache", "deployment", "tracer"):
        assert g in groups
    snap = reg.collect()
    assert snap["engine"]["n_requests"] >= 2
    assert snap["deployment"]["q/requests"] >= 2
    assert snap["tracer"]["spans_started"] >= 1
    text = reg.render_prometheus()
    assert "repro_engine_n_requests" in text
    assert 'repro_deployment_requests{item="q"}' in text
    assert "repro_tracer_spans_started" in text


def test_sharded_registry_includes_router_admission():
    se = ShardedEngine(ShardConfig(n_shards=2))
    try:
        keys, ts, rows = _events()
        se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
        se.insert("events", keys.tolist(), ts.tolist(), rows)
        se.deploy("q", SQL)
        se.request("q", list(range(4)), [2000.0] * 4)
        reg = registry_from_engine(se)
        groups = set(reg.groups())
        assert {"engine", "cache", "deployment", "admission", "router",
                "tracer"} <= groups
        if se.backend_kind == "process":
            assert {"transport", "recovery"} <= groups
        snap = reg.collect()
        assert snap["engine"].get("n_requests", 0) >= 4
    finally:
        se.close()


# ============================================================= telemetry
def test_collector_counter_reset_clamps_deltas():
    """A respawned worker resets its monotonic counters; interval deltas
    must clamp at 0, never go negative."""
    from repro.control.telemetry import MetricsCollector
    eng = _engine(sample=0.0)
    col = MetricsCollector(eng)
    col.sample()                            # establish baselines
    eng.request("q", list(range(4)), [2000.0] * 4)
    s = col.sample()
    assert s["engine_delta"]["n_requests"] >= 4
    eng.stats = EngineStats()               # simulate the reset
    s2 = col.sample()
    for k, v in s2["engine_delta"].items():
        assert v >= 0, (k, v)
    assert s2["engine_delta"]["n_requests"] == 0


def test_collector_shares_registry_with_exporters():
    from repro.control.telemetry import MetricsCollector
    eng = _engine(sample=0.0)
    col = MetricsCollector(eng)
    eng.request("q", [1], [2000.0])
    col.sample()
    assert "repro_engine_n_requests" in col.render_prometheus()
    doc = json.loads(col.render_jsonl(now=5.0))
    assert doc["t"] == 5.0 and doc["engine"]["n_requests"] >= 1


def test_ring_series_bounded_fifo():
    from repro.control.telemetry import RingSeries
    rs = RingSeries(maxlen=4)
    assert rs.last() is None and len(rs) == 0 and rs.mean() == 0.0
    for i in range(10):
        rs.append(float(i), float(i))
    assert len(rs) == 4
    assert rs.values() == [6.0, 7.0, 8.0, 9.0]   # oldest dropped
    assert rs.last() == 9.0
    assert rs.mean(2) == pytest.approx(8.5)
    js = rs.to_json()
    assert js["t"] == [6.0, 7.0, 8.0, 9.0]


# ====================================================== quantile sketch
def _core(d):
    """Bit-comparable sketch fields (``sum`` excluded: float addition
    order is topology-dependent)."""
    return {k: d[k] for k in ("rel_err", "pos", "neg", "zero", "count",
                              "min", "max")}


def test_sketch_merge_associative_and_commutative():
    from repro.obs.sketch import QuantileSketch
    rng = np.random.default_rng(5)
    parts = [rng.lognormal(0, 2.0, 500),
             -rng.lognormal(1.0, 1.0, 300),
             np.concatenate([np.zeros(50), rng.normal(0, 1e-3, 200)])]
    sks = []
    for p in parts:
        sk = QuantileSketch()
        sk.observe_many(p)
        sks.append(sk)
    a, b, c = (sk.to_dict() for sk in sks)

    def merged(*dicts):
        out = QuantileSketch()
        for d in dicts:
            out.merge(dict(d))
        return _core(out.to_dict())

    ab_c = merged(a, b, c)
    assert ab_c == merged(c, b, a)                    # commutative
    bc = QuantileSketch.from_dict(b).merge(dict(c)).to_dict()
    assert ab_c == merged(a, bc)                      # associative
    whole = QuantileSketch()
    whole.observe_many(np.concatenate(parts))
    assert ab_c == _core(whole.to_dict())             # merge == union
    for q in (1, 25, 50, 75, 99):
        assert QuantileSketch.from_dict(bc).merge(dict(a)).percentile(q) \
            == whole.percentile(q)


def test_sketch_serialization_deterministic_and_roundtrip():
    from repro.obs.sketch import QuantileSketch
    rng = np.random.default_rng(9)
    vals = rng.gamma(2.0, 3.0, 1000)
    s1, s2 = QuantileSketch(), QuantileSketch()
    s1.observe_many(vals)
    for chunk in np.split(rng.permutation(vals), 10):  # different order
        s2.observe_many(chunk)
    assert s1.to_bytes() != b""
    d1, d2 = s1.to_dict(), s2.to_dict()
    assert _core(d1) == _core(d2)           # order-independent
    rt = QuantileSketch.from_dict(json.loads(json.dumps(d1)))
    assert _core(rt.to_dict()) == _core(d1)
    assert rt.percentile(99) == s1.percentile(99)


def test_sketch_relative_error_bound_across_six_decades():
    """The DDSketch guarantee: every quantile estimate is within the
    configured relative error of the exact order statistic, on values
    spanning 1e-3 .. 1e3."""
    from repro.obs.sketch import QuantileSketch
    rng = np.random.default_rng(17)
    vals = 10.0 ** rng.uniform(-3, 3, 20000)
    sk = QuantileSketch(rel_err=0.01)
    sk.observe_many(vals)
    sv = np.sort(vals)
    for q in (0.1, 1, 5, 25, 50, 75, 95, 99, 99.9):
        exact = sv[int(q / 100.0 * (len(sv) - 1))]    # lower-interp rank
        got = sk.percentile(q)
        assert abs(got - exact) <= 0.0101 * exact, (q, got, exact)
    # negatives mirror the same bound
    skn = QuantileSketch(rel_err=0.01)
    skn.observe_many(-vals)
    svn = np.sort(-vals)
    exact = svn[int(0.01 * (len(svn) - 1))]
    assert abs(skn.percentile(1) - exact) <= 0.0101 * abs(exact)


def test_sketch_empty_and_zero_edge_cases():
    from repro.obs.sketch import QuantileSketch
    e = QuantileSketch()
    assert e.count == 0
    assert math.isnan(e.percentile(50))
    d = e.to_dict()
    assert d["count"] == 0 and d["pos"] == [] and d["neg"] == []
    m = QuantileSketch.merged([e, None, QuantileSketch()])
    assert m.count == 0 and math.isnan(m.percentile(99))
    # merging an empty into a live sketch is the identity
    live = QuantileSketch()
    live.observe_many([1.0, 2.0, 3.0])
    before = _core(live.to_dict())
    live.merge(e.to_dict())
    assert _core(live.to_dict()) == before
    # pure zeros: all mass in the zero bucket, percentiles are 0
    z = QuantileSketch()
    z.observe_many(np.zeros(10))
    assert z.to_dict()["zero"] == 10
    assert z.percentile(50) == 0.0
    with pytest.raises(ValueError):          # rel_err mismatch refuses
        z.merge(QuantileSketch(rel_err=0.05).to_dict())


def test_rolling_sketch_time_panes_and_monotonic_len():
    from repro.obs.sketch import RollingSketch
    now = [0.0]
    rs = RollingSketch(window_s=1.0, clock=lambda: now[0])
    for _ in range(100):
        rs.observe(10.0)
    assert rs.percentile(50) == pytest.approx(10.0, rel=0.03)
    now[0] = 1.2                             # rotate: old pane held
    rs.observe(1.0)
    assert len(rs) == 101                    # monotonic total
    assert rs.window_count() == 101          # both panes still visible
    now[0] = 2.5                             # old pane rotates away
    rs.observe(1.0)
    assert rs.percentile(99) == pytest.approx(1.0, rel=0.03)
    assert len(rs) == 102                    # len never decreases
    rs.clear()
    assert len(rs) == 0 and math.isnan(rs.percentile(50))


def test_cardinality_estimator_exact_then_approx_and_merge():
    from repro.obs.sketch import CardinalityEstimator
    a = CardinalityEstimator(k=64)
    a.add_many(np.arange(50))
    assert a.estimate() == 50.0              # exact below k
    b = CardinalityEstimator(k=64)
    b.add_many(np.arange(25, 75))            # overlapping range
    m = CardinalityEstimator(k=64)
    m.merge(a.to_dict())
    m.merge(b.to_dict())
    assert m.estimate() == pytest.approx(75.0, rel=0.25)
    big = CardinalityEstimator(k=64)
    big.add_many(np.arange(100000))
    assert big.estimate() == pytest.approx(100000, rel=0.30)
