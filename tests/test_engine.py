"""Engine behaviour: deploy/request/offline, optimizer passes, plan cache,
latency decomposition, baselines — the paper's system surface."""
from dataclasses import replace as dataclasses_replace

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dsl
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema

SQL = """
SELECT SUM(amount) OVER w AS s,
       AVG(amount) OVER w AS a,
       STD(amount) OVER w AS sd,
       COUNT(amount) OVER w AS c,
       MAX(lat) OVER w AS mx
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)
"""


def make_engine(flags=OptFlags(), n_events=500, n_keys=16, seed=0):
    eng = Engine(flags)
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount", "lat", "lon"))
    eng.create_table(schema, max_keys=64, capacity=256, bucket_size=32)
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0, 1000, n_events)).astype(np.float32)
    rows = rng.normal(0, 2, size=(n_events, 3)).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    return eng, (keys, ts, rows)


def brute_force(keys, ts, rows, req_key, req_ts, w=50):
    """Host-side oracle. Engine semantics: the window covers the last ``w``
    STORED events with ts <= request ts (the request row itself is exposed
    to scalar expressions but not aggregated); empty windows -> 0."""
    out = {"s": [], "a": [], "sd": [], "c": [], "mx": []}
    for k, t in zip(req_key, req_ts):
        m = (keys == k) & (ts <= t)
        amounts = rows[m, 0][-w:]
        lats = rows[m, 1][-w:]
        n = len(amounts)
        out["c"].append(float(n))
        out["s"].append(amounts.sum() if n else 0.0)
        out["a"].append(amounts.mean() if n else 0.0)
        out["sd"].append(amounts.std() if n else 0.0)
        out["mx"].append(lats.max() if n else 0.0)
    return {k: np.asarray(v, np.float32) for k, v in out.items()}


@pytest.mark.parametrize("flags", [
    OptFlags(),                                           # everything on
    OptFlags(preagg=False),                               # naive windows
    OptFlags(query_opt=False, preagg=False),              # no rewrites
    OptFlags(vectorized=False),                           # row-at-a-time
])
def test_engine_matches_bruteforce(flags):
    """Online requests (ts past the ingest horizon — the assume_latest
    contract of the online fast path)."""
    eng, (keys, ts, rows) = make_engine(flags)
    dep = eng.deploy("f", SQL)
    rng = np.random.default_rng(1)
    B = 16
    rk = rng.integers(0, 16, B)
    rt = np.sort(rng.uniform(1100, 1500, B)).astype(np.float32)
    got = eng.request("f", rk.tolist(), rt.tolist())
    want = brute_force(keys, ts, rows, rk, rt)
    for name in ("s", "a", "sd", "c", "mx"):
        np.testing.assert_allclose(got[name], want[name], rtol=1e-3,
                                   atol=1e-3, err_msg=name)


def test_point_in_time_requests():
    """assume_latest=False: request ts inside history must see only events
    up to that ts (offline / point-in-time semantics)."""
    eng, (keys, ts, rows) = make_engine(
        OptFlags(assume_latest=False))
    eng.deploy("f", SQL)
    rng = np.random.default_rng(2)
    B = 16
    rk = rng.integers(0, 16, B)
    rt = np.sort(rng.uniform(200, 1500, B)).astype(np.float32)
    got = eng.request("f", rk.tolist(), rt.tolist())
    want = brute_force(keys, ts, rows, rk, rt)
    for name in ("s", "a", "sd", "c", "mx"):
        np.testing.assert_allclose(got[name], want[name], rtol=1e-3,
                                   atol=1e-3, err_msg=name)


def test_optimizer_pass_log_and_impl_choice():
    eng, _ = make_engine()
    dep = eng.deploy("f", SQL)
    log = "\n".join(dep.opt_log)
    assert "decompose_aggregates" in log       # AVG/STD -> moments
    assert "cse" in log                        # shared SUM/COUNT
    assert any(g.impl == "preagg" for g in dep.phys.groups)
    # naive chosen when preagg disabled
    eng2, _ = make_engine(OptFlags(preagg=False))
    dep2 = eng2.deploy("f", SQL)
    assert all(g.impl == "naive" for g in dep2.phys.groups)


def test_plan_cache_hits_across_batches():
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    for i in range(5):
        eng.request("f", keys[:7].tolist(), (ts[:7] + 2000 + i).tolist())
    st = eng.cache.stats
    assert st.hits >= 4                        # first compiles, rest hit
    assert eng.latency_decomposition()["cache_hit_rate"] > 0.5


def test_shape_bucketing_reuses_plans():
    from repro.core.plan_cache import bucket_batch
    assert bucket_batch(1) == 1
    assert bucket_batch(3) == 4
    assert bucket_batch(5) == 8
    assert bucket_batch(64) == 64
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    eng.request("f", keys[:5].tolist(), (ts[:5] + 2000).tolist())
    eng.request("f", keys[:7].tolist(), (ts[:7] + 2001).tolist())  # same 8
    assert eng.cache.stats.misses == 1
    assert eng.cache.stats.hits == 1


def test_latency_decomposition_populated():
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    eng.request("f", keys[:4].tolist(), (ts[:4] + 2000).tolist())
    d = eng.latency_decomposition()
    assert d["parse_s"] > 0 and d["plan_s"] > 0 and d["exec_s"] > 0
    assert d["n_requests"] == 4


def test_where_clause_filters_events():
    eng, (keys, ts, rows) = make_engine()
    q = """SELECT COUNT(amount) OVER w AS c FROM events
           WHERE amount > 0
           WINDOW w AS (PARTITION BY user ORDER BY ts
                        ROWS BETWEEN 100 PRECEDING AND CURRENT ROW)"""
    eng.deploy("fw", q)
    rk, rt = keys[:8], ts[:8] + 2000
    got = eng.request("fw", rk.tolist(), rt.tolist())
    for k, t, c in zip(rk, rt, got["c"]):
        m = (keys == k) & (ts <= t)
        # WHERE applies inside the last-100 row window
        want = (rows[m, 0][-100:] > 0).sum()
        assert c == pytest.approx(want, abs=1e-4)


def test_query_builder_equivalent_to_sql():
    eng, (keys, ts, _) = make_engine()
    eng.deploy("sql", SQL)
    qb = (dsl.QueryBuilder("events")
          .window("w", partition_by="user", order_by="ts", rows=50)
          .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                  a=dsl.avg_(dsl.col("amount")).over("w"),
                  sd=dsl.std_(dsl.col("amount")).over("w"),
                  c=dsl.count_(dsl.col("amount")).over("w"),
                  mx=dsl.max_(dsl.col("lat")).over("w")))
    eng.deploy("py", qb)
    rk, rt = keys[:6].tolist(), (ts[:6] + 3000).tolist()
    a = eng.request("sql", rk, rt)
    b = eng.request("py", rk, rt)
    for name in a:
        np.testing.assert_allclose(a[name], b[name], rtol=1e-6)


def test_model_udf_predict():
    """PREDICT(model, features...) — the +ML part of SQL+ML."""
    eng, (keys, ts, _) = make_engine()
    w = np.asarray([0.5, -0.25], np.float32)

    def scorer(params, feats):
        return jnp.asarray(feats) @ jnp.asarray(params)

    eng.register_model("scorer", scorer, w)
    q = """SELECT SUM(amount) OVER w AS fs,
                  COUNT(amount) OVER w AS fc,
                  PREDICT(scorer, fs, fc) AS score
           FROM events
           WINDOW w AS (PARTITION BY user ORDER BY ts
                        ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)"""
    eng.deploy("ml", q)
    got = eng.request("ml", keys[:5].tolist(), (ts[:5] + 2000).tolist())
    plain = eng.deploy("plain", SQL)
    feats = eng.request("plain", keys[:5].tolist(), (ts[:5] + 2000).tolist())
    want = feats["s"] * 0.5 - 0.25 * feats["c"]
    np.testing.assert_allclose(got["score"], want, rtol=1e-4, atol=1e-4)


def test_baseline_profiles_agree_on_results():
    """All emulated engines must compute identical features (they differ
    only in execution model / speed)."""
    from repro.core.baselines import BaselineRunner, make_engine as mk
    results = {}
    for profile in ("openmldb", "row_interpreter", "microbatch",
                    "columnar_scan"):
        eng = mk(profile)
        schema = TableSchema("events", key_col="user", ts_col="ts",
                             value_cols=("amount", "lat", "lon"))
        eng.create_table(schema, max_keys=64, capacity=256, bucket_size=32)
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 16, 500)
        ts = np.sort(rng.uniform(0, 1000, 500)).astype(np.float32)
        rows = rng.normal(0, 2, (500, 3)).astype(np.float32)
        eng.insert("events", keys.tolist(), ts.tolist(), rows)
        eng.deploy("f", SQL)
        r = BaselineRunner(eng, "f", profile)
        out = r.serve_batch(keys[:10].tolist(), (ts[:10] + 2000).tolist())
        results[profile] = out
    base = results["openmldb"]
    for profile, out in results.items():
        for name in base:
            np.testing.assert_allclose(
                out[name], base[name], rtol=1e-3, atol=1e-3,
                err_msg=f"{profile}:{name}")


# ---------------------------------------------------------------------------
# versioned deployment handles: hot swap, rollback, canary, structured results
# ---------------------------------------------------------------------------

SQL_SHORT = SQL.replace("50 PRECEDING", "5 PRECEDING")


def test_redeploy_hot_swap_prewarmed_and_rollback():
    import threading
    eng, (keys, ts, rows) = make_engine()
    h1 = eng.deploy("f", SQL)
    assert h1.version == 1 and h1.live
    rk, rt = keys[:8].tolist(), (ts[:8] + 2000).tolist()
    v1_out = eng.request("f", rk, rt)
    assert v1_out.version == 1 and v1_out.all_ok

    h2 = eng.deploy("f", SQL_SHORT)
    assert h2.version == 2 and h2.live and h1.state == "retired"
    assert eng.registry.get("f").version == 2
    # retired version's executables were invalidated (different plan)
    assert eng.cache.stats.invalidations > 0
    # all buckets v1 served were pre-warmed before the swap: requesting
    # the same batch shape on v2 must not compile
    misses = eng.cache.stats.misses
    v2_out = eng.request("f", rk, rt)
    assert eng.cache.stats.misses == misses
    assert v2_out.version == 2
    assert not np.allclose(v2_out["s"], v1_out["s"])   # 5- vs 50-row window

    # rollback is swap-only: retired handles keep their executables
    prev = eng.rollback("f")
    assert prev is h1 and prev.live and h2.state == "retired"
    assert eng.registry.get("f").version == 1
    v1_again = eng.request("f", rk, rt)
    assert eng.cache.stats.misses == misses
    assert v1_again.version == 1
    np.testing.assert_allclose(v1_again["s"], v1_out["s"], rtol=1e-6)
    # the displaced version joined the history: rollback toggles back
    assert eng.rollback("f") is h2
    assert eng.request("f", rk, rt).version == 2
    with pytest.raises(ValueError, match="no prior version"):
        eng.rollback("nope")
    eng.close()


def test_redeploy_under_concurrent_traffic_no_mix():
    import threading
    import time as _time
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    rk, rt = keys[:4].tolist(), (ts[:4] + 2000).tolist()
    eng.request("f", rk, rt)                       # compile bucket 4
    stop = threading.Event()
    frames, errors = [], []

    def hammer():
        i = 0
        while not stop.is_set():
            i += 1
            try:
                frames.append(eng.request("f", rk, [t + i for t in rt]))
            except Exception as e:                 # pragma: no cover
                errors.append(e)
                return

    th = threading.Thread(target=hammer)
    th.start()
    try:
        eng.deploy("f", SQL_SHORT)                 # hot swap under load
        _time.sleep(0.2)
    finally:
        stop.set()
        th.join(10.0)
    assert not errors
    versions = {f.version for f in frames}
    assert versions <= {1, 2} and 2 in versions
    for f in frames:                               # every response coherent
        assert set(f.keys()) == {"s", "a", "sd", "c", "mx"}
        assert f.all_ok
    eng.close()


def test_canary_deploy_compare_promote_and_abort():
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    rk, rt = keys[:4].tolist(), (ts[:4] + 2000).tolist()
    eng.request("f", rk, rt)
    cand = eng.deploy("f", SQL, canary=0.5)        # identical query
    assert cand.state == "canary" and eng.handle("f").version == 1
    vers = [eng.request("f", rk, rt).version for _ in range(6)]
    assert set(vers) == {1, 2}                     # ~half routed to canary
    assert cand.metrics.canary_batches >= 2
    assert cand.metrics.canary_max_abs_diff < 1e-4  # same query, same answers
    live = eng.promote("f")
    assert live is cand and live.live
    assert eng.request("f", rk, rt).version == 2
    with pytest.raises(ValueError, match="no active canary"):
        eng.promote("f")
    # aborting a canary keeps the incumbent live
    c2 = eng.deploy("f", SQL_SHORT, canary=0.25)
    back = eng.rollback("f")
    assert back.version == 2 and c2.state == "retired"
    assert eng.request("f", rk, rt).version == 2
    # a redeploy over an active canary retires (not orphans) the canary:
    # unpinnable, pruned from the version map, incumbent's traffic intact
    c3 = eng.deploy("f", SQL_SHORT, canary=0.25)
    h4 = eng.deploy("f", SQL)
    assert c3.state == "retired"
    assert c3.version not in eng._versions["f"]
    assert h4.live and eng.request("f", rk, rt).version == h4.version
    # and canary on a fresh name is refused, not silently ignored
    with pytest.raises(ValueError, match="requires an existing live"):
        eng.deploy("g_fresh", SQL, canary=0.5)
    eng.close()


def test_unknown_key_masked_with_status():
    from repro.core.results import STATUS_OK, STATUS_UNKNOWN_KEY
    eng, (keys, ts, rows) = make_engine()
    eng.deploy("f", SQL)
    rk = [int(keys[0]), 9999]                      # second key never ingested
    rt = [float(ts.max()) + 10.0] * 2
    out = eng.request("f", rk, rt)
    assert list(out.status) == [STATUS_OK, STATUS_UNKNOWN_KEY]
    assert out.n_unknown == 1 and not out.all_ok
    for n in ("s", "a", "sd", "c", "mx"):
        assert out[n][1] == 0.0                    # masked, not garbage
    want = brute_force(keys, ts, rows, np.asarray(rk[:1]),
                       np.asarray(rt[:1], np.float32))
    np.testing.assert_allclose(out["s"][:1], want["s"], rtol=1e-3, atol=1e-3)
    assert eng.handle("f").metrics.unknown_keys == 1
    eng.close()


# ---------------------------------------------------------------------------
# fused multi-window execution + device-resident key directory
# ---------------------------------------------------------------------------

SQL_MULTI = """
SELECT SUM(amount) OVER w1 AS s1, LAST(amount) OVER w1 AS l1,
       AVG(amount) OVER w2 AS a2, LAST(amount) OVER w2 AS l2,
       STD(amount) OVER w3 AS d3, LAST(lat) OVER w3 AS l3,
       SUM(amount*amount) OVER w4 AS q4, LAST(amount) OVER w4 AS l4
FROM events
WINDOW w1 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 5 PRECEDING AND CURRENT ROW),
       w2 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
       w3 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 20 PRECEDING AND CURRENT ROW),
       w4 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 40 PRECEDING AND CURRENT ROW)
"""


@pytest.mark.parametrize("point_in_time", [False, True])
def test_fused_multiwindow_matches_pergroup_single_launch(point_in_time):
    """≥4 distinct plain window specs execute in ONE fused kernel launch
    (kernel_launches counter) with outputs equal to the per-group path."""
    flags = OptFlags(assume_latest=not point_in_time)
    eng_f, (keys, ts, _) = make_engine(flags)
    eng_p, _ = make_engine(dataclasses_replace(flags, fuse_windows=False))
    hf = eng_f.deploy("m", SQL_MULTI)
    hp = eng_p.deploy("m", SQL_MULTI)
    assert all(g.impl == "fused" for g in hf.phys.groups)
    assert hf.phys.n_kernel_launches == 1
    assert all(g.impl == "naive" for g in hp.phys.groups)
    assert hp.phys.n_kernel_launches == 4
    assert "fused scan: 4 window(s) in ONE launch" in eng_f.explain("m")
    assert any("fuse_windows" in l for l in hf.opt_log)

    rng = np.random.default_rng(7)
    rk = rng.integers(0, 16, 16).tolist()
    lo, hi = (200, 900) if point_in_time else (1100, 1500)
    rt = np.sort(rng.uniform(lo, hi, 16)).astype(np.float32).tolist()
    a = eng_f.request("m", rk, rt)
    b = eng_p.request("m", rk, rt)
    for name in a.keys():
        np.testing.assert_allclose(a[name], b[name], rtol=1e-3, atol=1e-3,
                                   err_msg=name)
    # the counter observes the fusion win: one batch = one launch
    assert eng_f.latency_decomposition()["kernel_launches"] == 1
    assert eng_p.latency_decomposition()["kernel_launches"] == 4
    eng_f.close()
    eng_p.close()


def test_fused_multiwindow_with_where_clause():
    """WHERE pushes every window onto the raw-scan path — they still fuse
    and still agree with the per-group execution (shared event mask)."""
    q = """SELECT COUNT(amount) OVER w1 AS c1, SUM(amount) OVER w2 AS s2,
                  MAX(amount) OVER w3 AS m3, AVG(amount) OVER w4 AS a4
           FROM events WHERE amount > 0
           WINDOW w1 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 8 PRECEDING AND CURRENT ROW),
                  w2 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 16 PRECEDING AND CURRENT ROW),
                  w3 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 32 PRECEDING AND CURRENT ROW),
                  w4 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 64 PRECEDING AND CURRENT ROW)"""
    eng_f, (keys, ts, _) = make_engine()
    eng_p, _ = make_engine(OptFlags(fuse_windows=False))
    hf = eng_f.deploy("fw", q)
    eng_p.deploy("fw", q)
    assert hf.phys.n_kernel_launches == 1
    rk, rt = keys[:8].tolist(), (ts[:8] + 2000).tolist()
    a = eng_f.request("fw", rk, rt)
    b = eng_p.request("fw", rk, rt)
    for name in a.keys():
        np.testing.assert_allclose(a[name], b[name], rtol=1e-3, atol=1e-3,
                                   err_msg=name)
    eng_f.close()
    eng_p.close()


def test_fuse_windows_pulls_shared_column_preagg():
    """A preagg-eligible window whose columns the fused scan already
    streams is pulled into the shared scan (marginal cost ~0)."""
    q = """SELECT LAST(amount) OVER w1 AS l1, LAST(amount) OVER w2 AS l2,
                  SUM(amount) OVER w3 AS s3
           FROM events
           WINDOW w1 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 5 PRECEDING AND CURRENT ROW),
                  w2 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
                  w3 AS (PARTITION BY user ORDER BY ts
                         ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)"""
    eng, (keys, ts, rows) = make_engine()
    dep = eng.deploy("p", q)
    impl = {g.name: g.impl for g in dep.phys.groups}
    assert impl == {"w1": "fused", "w2": "fused", "w3": "fused"}
    assert dep.phys.n_kernel_launches == 1
    assert any("pulled 'w3'" in l for l in dep.opt_log)
    # and it still computes the right SUM
    got = eng.request("p", keys[:6].tolist(), (ts[:6] + 2000).tolist())
    want = brute_force(keys, ts, rows, keys[:6], ts[:6] + 2000, w=20)
    np.testing.assert_allclose(got["s3"], want["s"], rtol=1e-3, atol=1e-3)
    eng.close()


def test_device_key_directory_matches_dict_fallback():
    """The device-resident key lookup must agree with the host dict loop
    on hits, misses, and masking."""
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    assert eng.tables["events"].keydir.active
    rk = [int(keys[0]), 9999, int(keys[1]), -7]     # 2 known, 2 unknown
    rt = [float(ts.max()) + 10.0] * 4
    fast = eng.request("f", rk, rt)
    eng.tables["events"].keydir.active = False      # force dict fallback
    slow = eng.request("f", rk, rt)
    assert list(fast.status) == list(slow.status)
    for n in fast.keys():
        np.testing.assert_allclose(fast[n], slow[n], rtol=1e-6,
                                   err_msg=n)
    eng.close()


def test_key_directory_incremental_patch_after_new_keys():
    """Keys ingested after the device mirror is built must be visible via
    the incremental scatter patch (no full re-upload, no stale misses)."""
    eng, (keys, ts, _) = make_engine()
    eng.deploy("f", SQL)
    t_now = float(ts.max()) + 10.0
    first = eng.request("f", [int(keys[0]), 777], [t_now] * 2)
    assert list(first.status) == [0, 1]             # 777 unknown so far
    eng.insert("events", [777], [t_now + 1.0],
               np.ones((1, 3), np.float32))
    out = eng.request("f", [int(keys[0]), 777], [t_now + 2.0] * 2)
    assert list(out.status) == [0, 0]               # patched in, now found
    assert out["c"][1] == pytest.approx(1.0)
    eng.close()


def test_key_directory_deactivates_on_non_integer_keys():
    from repro.featurestore.table import TableSchema as TS
    eng = Engine(OptFlags())
    eng.create_table(TS("ev", key_col="k", ts_col="ts",
                        value_cols=("x",)), max_keys=8, capacity=64,
                     bucket_size=8)
    eng.insert("ev", ["alice", "bob"], [1.0, 2.0],
               np.ones((2, 1), np.float32))
    t = eng.tables["ev"]
    assert not t.keydir.active                      # strings deactivate it
    q = """SELECT SUM(x) OVER w AS s FROM ev
           WINDOW w AS (PARTITION BY k ORDER BY ts
                        ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)"""
    eng.deploy("f", q)
    out = eng.request("f", ["alice", "carol"], [10.0, 10.0])
    assert out.status[0] == 0 and out.status[1] == 1
    np.testing.assert_allclose(out["s"][0], 1.0, rtol=1e-6)
    eng.close()


def test_engine_context_manager_and_idempotent_close():
    with Engine(OptFlags(parallel_workers=2)) as eng:
        assert eng._pool is not None
        eng.close()
        eng.close()                                # second close is a no-op
        assert eng._pool is None


def test_request_async_matches_sync():
    eng, (keys, ts, _) = make_engine()
    h = eng.deploy("f", SQL)
    rk, rt = keys[:4].tolist(), (ts[:4] + 2000).tolist()
    sync = h.request(rk, rt)
    out = h.request_async(rk, rt).result(timeout=60)
    assert out.version == sync.version
    np.testing.assert_allclose(out["s"], sync["s"], rtol=1e-6)
    eng.close()


def test_predict_with_expression_arguments_end_to_end():
    eng, (keys, ts, _) = make_engine()

    def scorer(params, feats):
        return jnp.asarray(feats) @ jnp.asarray(params)

    eng.register_model("scorer", scorer,
                       np.asarray([1.0, 0.5], np.float32))
    q = """SELECT SUM(amount) OVER w AS fs,
                  PREDICT(scorer, fs + 1, COUNT(amount) OVER w * 2) AS score
           FROM events
           WINDOW w AS (PARTITION BY user ORDER BY ts
                        ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)"""
    eng.deploy("mlx", q)
    got = eng.request("mlx", keys[:5].tolist(), (ts[:5] + 2000).tolist())
    eng.deploy("plainx", SQL)
    feats = eng.request("plainx", keys[:5].tolist(), (ts[:5] + 2000).tolist())
    want = (feats["s"] + 1.0) * 1.0 + 0.5 * (feats["c"] * 2.0)
    np.testing.assert_allclose(got["score"], want, rtol=1e-4, atol=1e-4)
    eng.close()
