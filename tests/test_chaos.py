"""Chaos tier (DESIGN.md §12): seeded fault injection on the worker
transport, CRC frame integrity, retry/backoff + idempotent dedup,
kill-mid-RPC, and the SIGKILL-under-live-traffic acceptance — bounded
degraded window, zero hung futures, bit-identical recovery via WAL +
warm standby.

Worker spawn imports jax (~seconds); the process-backend tests keep
shard counts at 2 and reuse engines across asserts.
"""
import os
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.core.results import (STATUS_DEGRADED, STATUS_OK, STATUS_SHED,
                                RequestContext)
from repro.featurestore.table import TableSchema
from repro.shard import ShardConfig, ShardedEngine
from repro.shard.proc.faults import FaultInjector, FaultPlan
from repro.shard.proc.transport import Channel, FrameCorrupt

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""
SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))


def _events(n=300, n_keys=8, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n)
    ts = np.sort(rng.uniform(0, 1000.0, n)).astype(np.float32)
    rows = np.stack(
        [rng.normal(size=n),
         rng.integers(0, 4, n).astype(np.float64)], -1).astype(np.float32)
    return keys, ts, rows


# ------------------------------------------------------------- fault plan
def test_fault_plan_parse_and_env(monkeypatch):
    p = FaultPlan.parse("seed=7,drop=0.05,dup=0.1,kill_after=40")
    assert (p.seed, p.drop, p.duplicate, p.kill_after) == (7, .05, .1, 40)
    assert p.active
    assert not p.disarmed().active or p.disarmed().kill_after == 0
    assert not FaultPlan().active
    with pytest.raises(ValueError, match="unknown FaultPlan field"):
        FaultPlan.parse("seed=1,typo=0.5")
    monkeypatch.setenv("REPRO_FAULT_PLAN", "seed=3,corrupt=0.2")
    assert FaultPlan.from_env().corrupt == 0.2
    monkeypatch.setenv("REPRO_FAULT_PLAN", "")
    assert FaultPlan.from_env() is None


def test_fault_injector_seeded_replayable():
    plan = FaultPlan(seed=11, drop=0.3, duplicate=0.3, corrupt=0.2)
    outs = []
    for _ in range(2):  # same plan+role => identical fault sequence
        inj = FaultInjector(plan, role="client-0")
        outs.append([len(inj.frames(b"payload-%d" % i))
                     for i in range(200)])
    assert outs[0] == outs[1]
    assert 0 in outs[0] and 2 in outs[0]   # drops and dups both occurred
    # a different role draws an independent stream
    inj2 = FaultInjector(plan, role="worker-0")
    assert [len(inj2.frames(b"payload-%d" % i))
            for i in range(200)] != outs[0]


def test_fault_injector_kill_fires_once():
    fired = []
    plan = FaultPlan(kill_after=3)
    inj = FaultInjector(plan, role="x", kill_cb=lambda: fired.append(1))
    for i in range(6):
        inj.frames(b"f%d" % i)
    assert fired == [1]                    # not re-fired on frames 4..6
    assert inj.stats["killed"] == 1


# ------------------------------------------------------------- transport
def test_channel_crc_detects_corruption_and_stays_aligned():
    a, b = socket.socketpair()
    ca, cb = Channel(a), Channel(b)
    plan = FaultPlan(seed=5, corrupt=1.0)  # corrupt EVERY frame
    ca.fault_injector = FaultInjector(plan, role="t")
    ca.send((1, "m", b"x"))
    with pytest.raises(FrameCorrupt):
        cb.recv()
    # stream still aligned: a clean frame right after parses fine
    ca.fault_injector = None
    ca.send((2, "ok", b"y"))
    assert cb.recv() == (2, "ok", b"y")
    ca.close()
    cb.close()


def test_channel_duplicate_frames_arrive_twice():
    a, b = socket.socketpair()
    ca, cb = Channel(a), Channel(b)
    ca.fault_injector = FaultInjector(FaultPlan(seed=1, duplicate=1.0),
                                      role="t")
    ca.send((7, "m", b"z"))
    assert cb.recv() == (7, "m", b"z")
    assert cb.recv() == (7, "m", b"z")     # the dedup layer's problem
    ca.close()
    cb.close()


# -------------------------------------------------- chaos traffic (proc)
def test_chaos_traffic_all_ok_through_retries():
    """Seeded drop/dup/corrupt faults on every channel: at-least-once
    delivery + worker dedup + CRC re-reads must yield bit-exact all-OK
    service — the chaos is invisible above the transport."""
    keys, ts, rows = _events()
    plan = FaultPlan(seed=7, drop=0.03, duplicate=0.05, corrupt=0.03)
    se = ShardedEngine(ShardConfig(n_shards=2, fault_plan=plan),
                       backend="process")
    ref = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    try:
        for eng in (se, ref):
            eng.create_table(SCHEMA, max_keys=64, capacity=64,
                             bucket_size=8)
            eng.insert("events", keys.tolist(), ts.tolist(), rows)
            eng.deploy("q", SQL)
        rk, rt = list(range(8)), [2000.0] * 8
        for _ in range(6):
            fr = se.request("q", rk, rt)
            assert (np.asarray(fr.status) == STATUS_OK).all()
        clean = ref.request("q", rk, rt)
        for c in clean.columns:
            assert np.array_equal(np.asarray(clean[c]),
                                  np.asarray(fr[c])), c
        dec = se.latency_decomposition()
        # the plan actually bit: retries and/or corrupt frames happened
        assert (dec["transport_retries"] > 0
                or dec["transport_frame_corrupt"] > 0)
    finally:
        se.close()
        ref.close()


def test_chaos_kill_after_mid_rpc_sheds_then_recovers():
    """kill_after SIGKILLs a worker ON an outbound frame — the caller is
    left holding an in-flight RPC. It must shed/degrade (never hang,
    never raise); the supervisor respawns, and service resumes. Each
    client role draws its own fault stream, so BOTH workers eventually
    die at their own 40th frame — serving must survive both."""
    keys, ts, rows = _events()
    plan = FaultPlan(seed=3, kill_after=40)
    se = ShardedEngine(
        ShardConfig(n_shards=2, fault_plan=plan, standby_workers=1),
        backend="process")
    try:
        se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
        pipe = se.attach_stream("events", flush_interval_s=0.05)
        pipe.push_batch(keys, ts, rows)
        pipe.flush()
        se.deploy("q", SQL)
        rk, rt = list(range(8)), [2000.0] * 8
        deadline = time.time() + 150
        while time.time() < deadline:
            fr = se.request("q", rk, rt)       # must never raise or hang
            st = set(np.asarray(fr.status).tolist())
            if (se.worker_restarts >= 2
                    and STATUS_SHED not in st
                    and STATUS_DEGRADED not in st):
                break
            time.sleep(0.05)
        assert se.worker_restarts >= 2         # both kills actually fired
        # respawned workers run DISARMED plans — re-ingest sticks and
        # full service returns (no WAL in this test: producer replays)
        deadline = time.time() + 60
        while time.time() < deadline:
            try:
                pipe.push_batch(keys, ts + 3000.0, rows)
                pipe.flush()
                fr = se.request("q", rk, [9000.0] * 8)
                if (np.asarray(fr.status) == STATUS_OK).all():
                    break
            except Exception:                  # noqa: BLE001 — retryable
                pass
            time.sleep(0.1)
        assert (np.asarray(fr.status) == STATUS_OK).all()
    finally:
        se.close()


def test_chaos_sigkill_under_live_traffic_bit_identical():
    """The §12 acceptance: SIGKILL one shard under continuous ingest +
    serve. Requirements — zero hung futures (every request returns
    within its deadline), a bounded DEGRADED/SHED window, no permanent
    UNKNOWN_KEY, and post-recovery output bit-identical to a never-
    killed twin fed the same events."""
    keys, ts, rows = _events(n=240)
    extra_ts = np.linspace(1500.0, 1600.0, 40).astype(np.float32)
    extra_keys = np.arange(40) % 8
    extra_rows = np.ones((40, 2), np.float32)

    import tempfile
    wal_dir = tempfile.mkdtemp(prefix="chaos-wal-")
    twin = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se = ShardedEngine(
        ShardConfig(n_shards=2, wal_dir=wal_dir, standby_workers=1),
        backend="process")
    try:
        for eng in (se, twin):
            eng.create_table(SCHEMA, max_keys=64, capacity=64,
                             bucket_size=8)
            pipe = eng.attach_stream("events", flush_interval_s=0.05)
            pipe.push_batch(keys, ts, rows)
            pipe.flush()
            eng.deploy("q", SQL)
        rk, rt = list(range(8)), [2500.0] * 8
        assert (np.asarray(se.request("q", rk, rt).status)
                == STATUS_OK).all()

        stop = threading.Event()
        hung, errors = [], []

        def serve_loop():
            while not stop.is_set():
                t0 = time.perf_counter()
                try:
                    se.request("q", rk, rt,
                               ctx=RequestContext.with_timeout(5.0))
                except Exception as e:       # noqa: BLE001
                    errors.append(repr(e))
                if time.perf_counter() - t0 > 30.0:
                    hung.append(time.perf_counter() - t0)
                time.sleep(0.01)

        t = threading.Thread(target=serve_loop, daemon=True)
        t.start()
        time.sleep(0.2)
        os.kill(se.shards[1].proc.pid, signal.SIGKILL)

        # live ingest DURING the outage — through the 2PC transactional
        # path, because that is what makes a producer retry SAFE: a
        # failed attempt (dead shard can't prepare) aborts the prepared
        # slice on the live shard, so nothing lands twice. A raw
        # push_batch retry would double-apply the live shard's slice.
        pushed = False
        for _ in range(600):
            try:
                se.insert("events", extra_keys.tolist(),
                          extra_ts.tolist(), extra_rows)
                se.streams["events"].flush()
                pushed = True
                break
            except Exception:
                time.sleep(0.1)
        assert pushed, "ingest never recovered after the kill"

        # full parity: all-OK again within a bounded window
        deadline = time.time() + 90
        recovered = False
        while time.time() < deadline:
            fr = se.request("q", rk, [3000.0] * 8)
            if (np.asarray(fr.status) == STATUS_OK).all():
                recovered = True
                break
            time.sleep(0.05)
        stop.set()
        t.join(timeout=10)
        assert recovered, f"stuck at {np.asarray(fr.status).tolist()}"
        assert not hung, f"requests hung: {hung}"
        assert not errors, f"requests raised: {errors[:3]}"
        assert se.worker_restarts == 1

        # twin gets the same late batch; outputs must be bit-identical
        twin.insert("events", extra_keys.tolist(), extra_ts.tolist(),
                    extra_rows)
        twin.streams["events"].flush()
        a = twin.request("q", rk, [3000.0] * 8)
        b = se.request("q", rk, [3000.0] * 8)
        assert np.array_equal(np.asarray(a.status), np.asarray(b.status))
        for c in a.columns:
            assert np.array_equal(np.asarray(a[c]), np.asarray(b[c])), c
        # no permanent UNKNOWN_KEY: every key answered OK above
        dec = se.latency_decomposition()
        assert dec["recovery_wal_replays"] >= 1
        assert dec["recovery_last_adopted"] == 1.0   # standby was used
    finally:
        import shutil
        stop_ev = locals().get("stop")
        if stop_ev is not None:
            stop_ev.set()
        se.close()
        twin.close()
        shutil.rmtree(wal_dir, ignore_errors=True)


def test_degraded_ladder_stale_tier_inprocess_semantics():
    """The OK -> DEGRADED -> SHED ladder at the handle level, without
    subprocess spawn cost: a worker_down shed with every affected key
    stale-cached answers DEGRADED rows (mixed with fresh OK rows); an
    uncached key drops the whole batch to SHED."""
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2, degraded_cache_keys=64))
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)
    rk, rt = list(range(8)), [2000.0] * 8
    fr = se.request("q", rk, rt)
    assert (np.asarray(fr.status) == STATUS_OK).all()

    h = se.handle("q")
    down = {s for s in range(8) if se.shard_of(s) == 1}
    assert down and len(down) < 8

    # simulate shard 1 down by retiring its router queue: lanes shed
    # worker_down for its sub-batches
    se.router.retire_queue(1)
    fr2 = se.request("q", rk, rt)
    st = np.asarray(fr2.status)
    assert (st[[k in down for k in rk]] == STATUS_DEGRADED).all()
    assert (st[[k not in down for k in rk]] == STATUS_OK).all()
    # degraded rows reproduce the last-served values bit-exactly
    for c in fr.columns:
        assert np.array_equal(np.asarray(fr[c]), np.asarray(fr2[c])), c
    assert fr2.n_degraded == len(down)
    assert se.resources.metrics()["served_degraded"] >= len(down)
    m = h.metrics.snapshot()
    assert m["degraded_requests"] >= len(down)
    assert m["degraded_batches"] >= 1

    # an uncached key in the dead shard's range: whole batch SHED
    cold = next(k for k in range(8, 200)
                if se.shard_of(k) in {1} and k not in rk)
    fr3 = se.request("q", rk + [cold], rt + [2000.0])
    assert (np.asarray(fr3.status) == STATUS_SHED).all()
    se.close()
