"""Ring-buffer storage + pre-aggregate tier invariants (incl. hypothesis
property tests on the system's core invariant: preagg == rebuild)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.featurestore.preagg import (preagg_memory_overhead,
                                       rebuild_preagg, verify_preagg)
from repro.featurestore.table import Table, TableSchema
from conftest import make_table_with_events


def test_ring_buffer_positions_and_eviction():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    # 12 events for key 'a': first 4 must be evicted
    t.insert(["a"] * 12, list(range(12)), np.arange(12, dtype=np.float32)[:, None])
    st_ = t.state
    assert int(st_.total[0]) == 12
    vals = np.asarray(st_.values[0, :, 0])
    # slots hold positions 4..11 (ring layout: slot p % 8)
    for p in range(4, 12):
        assert vals[p % 8] == p
    assert t.memory_bytes() > 0


def test_out_of_order_ingest_rejected():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    t.insert(["a"], [5.0], np.zeros((1, 1), np.float32))
    with pytest.raises(ValueError, match="out-of-order"):
        t.insert(["a"], [4.0], np.zeros((1, 1), np.float32))


def test_key_space_exhaustion():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    t.insert(["a", "b"], [0.0, 0.0], np.zeros((2, 1), np.float32))
    with pytest.raises(RuntimeError, match="key space exhausted"):
        t.insert(["c"], [1.0], np.zeros((1, 1), np.float32))


def test_incremental_preagg_matches_rebuild():
    t, _ = make_table_with_events(n_keys=6, n_events=700, capacity=128,
                                  bucket_size=16, seed=3)
    ok, err = verify_preagg(t.state, t.preagg, bucket_size=16)
    assert ok, f"max err {err}"


def test_preagg_memory_overhead_bounded():
    t, _ = make_table_with_events(capacity=128, bucket_size=16)
    ovh = preagg_memory_overhead(t.state, t.preagg)
    # 4 stat tensors + count at 1/16 bucket granularity ≈ 4/16 + eps
    assert 0.1 < ovh < 0.5


@settings(max_examples=25, deadline=None)
@given(
    n_events=st.integers(1, 300),
    n_keys=st.integers(1, 5),
    bucket=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_property_preagg_invariant(n_events, n_keys, bucket, seed):
    """For ANY ingest pattern, live full buckets of the incremental tier
    equal a from-scratch rebuild (paper Eq. 2 correctness)."""
    rng = np.random.default_rng(seed)
    schema = TableSchema("t", "k", "ts", ("x", "y"))
    t = Table(schema, max_keys=n_keys, capacity=64, bucket_size=bucket)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0, 100, n_events)).astype(np.float32)
    rows = rng.normal(0, 3, (n_events, 2)).astype(np.float32)
    # ingest in random batch splits
    i = 0
    while i < n_events:
        j = min(n_events, i + int(rng.integers(1, 40)))
        t.insert(keys[i:j].tolist(), ts[i:j].tolist(), rows[i:j])
        i = j
    ok, err = verify_preagg(t.state, t.preagg, bucket_size=bucket,
                            atol=1e-2)
    assert ok, f"max err {err}"


@settings(max_examples=20, deadline=None)
@given(
    w=st.integers(1, 80),
    seed=st.integers(0, 10_000),
)
def test_property_preagg_window_equals_naive(w, seed):
    """Window aggregates via the preagg path == naive scan, for any
    window size (the optimizer's impl choice can never change results)."""
    from repro.kernels import ref
    t, _ = make_table_with_events(n_keys=4, n_events=300, capacity=128,
                                  bucket_size=16, seed=seed)
    st_, pa = t.state, t.preagg
    rng = np.random.default_rng(seed + 1)
    req_key = jnp.asarray(rng.integers(0, 4, 6), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(0, 1200, 6)), jnp.float32)
    naive = ref.window_agg_ref(st_.values, st_.ts, st_.total, req_key,
                               req_ts, rows_preceding=w)
    fast = ref.preagg_window_ref(st_.values, st_.ts, st_.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, bucket_size=16,
                                 rows_preceding=w)
    for name in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(np.asarray(fast[name]),
                                   np.asarray(naive[name]),
                                   rtol=5e-4, atol=5e-4, err_msg=name)


# ---------------------------------------------------------------------------
# KeyDirectory under key-slot pressure (device mirror of the key dict)
# ---------------------------------------------------------------------------

def _collision_chain(kd, n, start=1):
    """First ``n`` positive int32 keys whose initial probe slot collides
    with ``start``'s — forces a linear probe chain of length ``n``."""
    from repro.featurestore.keydir import _MULT
    target = ((start & 0xFFFFFFFF) * _MULT) & kd._mask
    out, k = [], start
    while len(out) < n:
        if ((k & 0xFFFFFFFF) * _MULT) & kd._mask == target:
            out.append(k)
        k += 1
    return out


def test_keydir_fills_to_slot_capacity_then_deactivates():
    """Directory at capacity: every slot usable; one key past the slot
    count deactivates it (fallback boundary), never corrupts it."""
    import numpy as np
    from repro.featurestore.keydir import KeyDirectory
    kd = KeyDirectory(max_keys=4)           # slots = next_pow2(8) = 16
    assert kd.slots == 16
    keys = list(range(100, 100 + kd.slots))
    for i, k in enumerate(keys):
        kd.insert(k, i)
    assert kd.active and kd.n == kd.slots
    idx, found = kd.lookup(np.asarray(keys))
    assert bool(np.asarray(found).all())
    np.testing.assert_array_equal(np.asarray(idx), np.arange(kd.slots))
    # 17th key: probe chain exhausts every slot -> permanent fallback
    kd.insert(999_999, 16)
    assert not kd.active
    assert not kd.covers(np.asarray([100]))  # engine takes the dict path


def test_keydir_colliding_probe_chains_resolve_exactly():
    """Keys hashing to the SAME initial slot must chain and still resolve
    to their own values (no aliasing), with max_probe ratcheting up."""
    import numpy as np
    from repro.featurestore.keydir import KeyDirectory
    kd = KeyDirectory(max_keys=8)           # slots = 16
    chain = _collision_chain(kd, 5)
    for i, k in enumerate(chain):
        kd.insert(k, 10 + i)
    assert kd.max_probe >= 5
    idx, found = kd.lookup(np.asarray(chain))
    assert bool(np.asarray(found).all())
    np.testing.assert_array_equal(np.asarray(idx),
                                  10 + np.arange(len(chain)))
    # a non-inserted key on the same chain misses (no false positive)
    probe_more = _collision_chain(kd, 6)[-1]
    idx, found = kd.lookup(np.asarray([probe_more]))
    assert not bool(np.asarray(found)[0])
    # re-insert idempotence: same (key, value) changes nothing
    n_before, mp_before = kd.n, kd.max_probe
    kd.insert(chain[0], 10)
    assert (kd.n, kd.max_probe) == (n_before, mp_before)


def test_keydir_fallback_boundary_int32_domain():
    """Keys outside the int32 domain deactivate the mirror; queries
    outside the domain are refused by covers() while the directory stays
    active for in-range keys."""
    import numpy as np
    from repro.featurestore.keydir import KeyDirectory
    kd = KeyDirectory(max_keys=8)
    kd.insert(42, 0)
    # out-of-domain QUERY: covers() says no, directory stays active
    assert not kd.covers(np.asarray([2 ** 40]))
    assert not kd.covers(np.asarray([-(2 ** 31)]))   # sentinel value
    assert kd.covers(np.asarray([42]))
    assert kd.active
    # out-of-domain INSERT: permanent deactivation
    kd.insert(2 ** 40, 1)
    assert not kd.active
    kd2 = KeyDirectory(max_keys=8)
    kd2.insert(True, 0)                    # bools are not keys
    assert not kd2.active
    kd3 = KeyDirectory(max_keys=8)
    kd3.insert(-(2 ** 31), 0)              # the EMPTY sentinel itself
    assert not kd3.active


def test_table_serving_survives_keydir_overflow():
    """Engine-level fallback boundary: more distinct keys than the
    directory can mirror must degrade to the host dict, not misroute."""
    import numpy as np
    from repro.core.engine import Engine
    from repro.core.optimizer import OptFlags
    eng = Engine(OptFlags())
    schema = TableSchema("ev", key_col="k", ts_col="ts", value_cols=("x",))
    eng.create_table(schema, max_keys=64, capacity=64, bucket_size=8)
    t = eng.tables["ev"]
    # force the mirror into fallback with an out-of-domain key, then keep
    # ingesting normal keys (the dict keeps growing past the mirror)
    eng.insert("ev", [2 ** 40], [0.0], np.ones((1, 1), np.float32))
    assert not t.keydir.active
    keys = list(range(40))
    eng.insert("ev", keys, [1.0] * 40, np.ones((40, 1), np.float32))
    eng.deploy("f", """SELECT COUNT(x) OVER w AS c FROM ev
                       WINDOW w AS (PARTITION BY k ORDER BY ts
                       ROWS BETWEEN 5 PRECEDING AND CURRENT ROW)""")
    out = eng.request("f", [2 ** 40, 7, 12345], [10.0] * 3)
    assert list(out.status) == [0, 0, 1]
    np.testing.assert_allclose(np.asarray(out["c"])[:2], [1.0, 1.0])
    assert np.asarray(out["c"])[2] == 0.0
    eng.close()
