"""Ring-buffer storage + pre-aggregate tier invariants (incl. hypothesis
property tests on the system's core invariant: preagg == rebuild)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.featurestore.preagg import (preagg_memory_overhead,
                                       rebuild_preagg, verify_preagg)
from repro.featurestore.table import Table, TableSchema
from conftest import make_table_with_events


def test_ring_buffer_positions_and_eviction():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    # 12 events for key 'a': first 4 must be evicted
    t.insert(["a"] * 12, list(range(12)), np.arange(12, dtype=np.float32)[:, None])
    st_ = t.state
    assert int(st_.total[0]) == 12
    vals = np.asarray(st_.values[0, :, 0])
    # slots hold positions 4..11 (ring layout: slot p % 8)
    for p in range(4, 12):
        assert vals[p % 8] == p
    assert t.memory_bytes() > 0


def test_out_of_order_ingest_rejected():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    t.insert(["a"], [5.0], np.zeros((1, 1), np.float32))
    with pytest.raises(ValueError, match="out-of-order"):
        t.insert(["a"], [4.0], np.zeros((1, 1), np.float32))


def test_key_space_exhaustion():
    schema = TableSchema("t", "k", "ts", ("x",))
    t = Table(schema, max_keys=2, capacity=8, bucket_size=4)
    t.insert(["a", "b"], [0.0, 0.0], np.zeros((2, 1), np.float32))
    with pytest.raises(RuntimeError, match="key space exhausted"):
        t.insert(["c"], [1.0], np.zeros((1, 1), np.float32))


def test_incremental_preagg_matches_rebuild():
    t, _ = make_table_with_events(n_keys=6, n_events=700, capacity=128,
                                  bucket_size=16, seed=3)
    ok, err = verify_preagg(t.state, t.preagg, bucket_size=16)
    assert ok, f"max err {err}"


def test_preagg_memory_overhead_bounded():
    t, _ = make_table_with_events(capacity=128, bucket_size=16)
    ovh = preagg_memory_overhead(t.state, t.preagg)
    # 4 stat tensors + count at 1/16 bucket granularity ≈ 4/16 + eps
    assert 0.1 < ovh < 0.5


@settings(max_examples=25, deadline=None)
@given(
    n_events=st.integers(1, 300),
    n_keys=st.integers(1, 5),
    bucket=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
def test_property_preagg_invariant(n_events, n_keys, bucket, seed):
    """For ANY ingest pattern, live full buckets of the incremental tier
    equal a from-scratch rebuild (paper Eq. 2 correctness)."""
    rng = np.random.default_rng(seed)
    schema = TableSchema("t", "k", "ts", ("x", "y"))
    t = Table(schema, max_keys=n_keys, capacity=64, bucket_size=bucket)
    keys = rng.integers(0, n_keys, n_events)
    ts = np.sort(rng.uniform(0, 100, n_events)).astype(np.float32)
    rows = rng.normal(0, 3, (n_events, 2)).astype(np.float32)
    # ingest in random batch splits
    i = 0
    while i < n_events:
        j = min(n_events, i + int(rng.integers(1, 40)))
        t.insert(keys[i:j].tolist(), ts[i:j].tolist(), rows[i:j])
        i = j
    ok, err = verify_preagg(t.state, t.preagg, bucket_size=bucket,
                            atol=1e-2)
    assert ok, f"max err {err}"


@settings(max_examples=20, deadline=None)
@given(
    w=st.integers(1, 80),
    seed=st.integers(0, 10_000),
)
def test_property_preagg_window_equals_naive(w, seed):
    """Window aggregates via the preagg path == naive scan, for any
    window size (the optimizer's impl choice can never change results)."""
    from repro.kernels import ref
    t, _ = make_table_with_events(n_keys=4, n_events=300, capacity=128,
                                  bucket_size=16, seed=seed)
    st_, pa = t.state, t.preagg
    rng = np.random.default_rng(seed + 1)
    req_key = jnp.asarray(rng.integers(0, 4, 6), jnp.int32)
    req_ts = jnp.asarray(np.sort(rng.uniform(0, 1200, 6)), jnp.float32)
    naive = ref.window_agg_ref(st_.values, st_.ts, st_.total, req_key,
                               req_ts, rows_preceding=w)
    fast = ref.preagg_window_ref(st_.values, st_.ts, st_.total, pa.sum,
                                 pa.sumsq, pa.min, pa.max, pa.count,
                                 req_key, req_ts, bucket_size=16,
                                 rows_preceding=w)
    for name in ("sum", "count", "min", "max"):
        np.testing.assert_allclose(np.asarray(fast[name]),
                                   np.asarray(naive[name]),
                                   rtol=5e-4, atol=5e-4, err_msg=name)
