"""Process-backed shard runtime (DESIGN.md §11): subprocess workers
behind the unchanged ShardedEngine API — parity vs in-process, broadcast
dimension ingest, cross-shard transactional insert, killed-worker shed
-> respawn -> recover (no hung futures), and elastic add_shard with a
fresh subprocess.

Worker spawn imports jax (~seconds); tests keep shard counts small and
reuse one engine across many asserts.
"""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import dsl
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.core.results import (STATUS_DEGRADED, STATUS_OK, STATUS_SHED,
                                STATUS_UNKNOWN_KEY)
from repro.featurestore.table import TableSchema
from repro.shard import ShardConfig, ShardedEngine

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c,
AVG(amount) OVER w AS a
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""

SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))
DIM = TableSchema("dim", key_col="mkey", ts_col="dts",
                  value_cols=("risk", "tier"))


def _events(n=400, n_keys=16, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n)
    ts = np.sort(rng.uniform(0, 1000.0, n)).astype(np.float32)
    rows = np.stack(
        [rng.normal(size=n),
         rng.integers(0, 4, n).astype(np.float64)], -1).astype(np.float32)
    return keys, ts, rows


def _join_query():
    return (dsl.QueryBuilder("events")
            .window("w", partition_by="user", order_by="ts", rows=10)
            .select(s=dsl.sum_(dsl.col("amount")).over("w"),
                    risk=dsl.tbl("dim").risk)
            .last_join("dim", on="mkey", order_by="dts"))


def test_proc_parity_lifecycle_and_offline():
    """One subprocess per shard, same API, bit-identical to the
    unsharded engine — online and offline — plus redeploy/rollback and
    telemetry-over-transport."""
    keys, ts, rows = _events()
    ref = Engine(OptFlags())
    ref.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    ref.insert("events", keys.tolist(), ts.tolist(), rows)
    ref.deploy("q", SQL)

    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    assert se.backend_kind == "process"
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("q", SQL)

    rk = list(range(16))
    rt = [2000.0] * 16
    a = ref.request("q", rk, rt)
    b = se.request("q", rk, rt)
    assert np.array_equal(a.status, b.status)
    for n in a:
        assert np.array_equal(np.asarray(a[n]), np.asarray(b[n])), n
    assert len(b.version_vector) == 2

    # offline parity: workers map dense indices -> real keys themselves
    oa = ref.query_offline("q")
    ob = se.query_offline("q")
    inv = {i: k for k, i in ref.tables["events"].key_to_idx.items()}
    ka = np.asarray([inv[int(i)] for i in oa["__key"]])
    ia = np.lexsort((oa["__ts"], ka))
    ib = np.lexsort((ob["__ts"], ob["__key"]))
    assert np.array_equal(ka[ia], ob["__key"][ib])
    for n in ("s", "c", "a"):
        assert np.array_equal(oa[n][ia], ob[n][ib]), n

    # redeploy + rollback run the serialized control RPCs on every worker
    se.deploy("q", SQL.replace("10 PRECEDING", "5 PRECEDING"))
    assert se.handle("q").version == 2
    se.rollback("q")
    b2 = se.request("q", rk, rt)
    for n in a:
        assert np.array_equal(np.asarray(a[n]), np.asarray(b2[n])), n

    # control-plane reads cross the transport (worker-side snapshots)
    dec = se.latency_decomposition()
    assert dec["n_shards"] == 2
    assert dec["n_requests"] >= 32
    for sub in se.shards:
        assert isinstance(sub.stats.snapshot(), dict)
    assert "process backend" in se.explain("q")
    ref.close()
    se.close()


def test_proc_broadcast_dimension_join():
    """Replicated dimension ingest is ONE serialized payload fanned to
    every worker; LAST JOIN probes resolve on the probing shard."""
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.create_table(DIM, max_keys=16, capacity=16, bucket_size=8,
                    replicate=True)
    drow = np.stack([np.arange(4) * 0.1, np.arange(4) * 1.0],
                    -1).astype(np.float32)
    se.insert("dim", list(range(4)), [1.0] * 4, drow)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("jq", _join_query())
    fr = se.request("jq", list(range(8)), [2000.0] * 8, rows=rows[:8])
    assert (fr.status == STATUS_OK).all()
    for i in range(8):
        assert abs(fr.columns["risk"][i] - rows[i, 1] * 0.1) < 1e-6
    st = se.handle("jq").join_staleness()["dim"]
    assert st["match_rate"] == 1.0
    se.close()


def test_proc_transactional_insert_all_or_nothing():
    """Cross-shard insert into a stream-attached table: one shard's
    unrepairably-late slice must reject the WHOLE batch — the other
    shard's slice is aborted, not applied (the pre-2PC partial-apply)."""
    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    pipe = se.attach_stream("events", lateness=1.0)
    ka = next(k for k in range(100) if se.shard_of(k) == 0)
    kb = next(k for k in range(100) if se.shard_of(k) == 1)
    se.insert("events", [ka], [100.0], np.ones((1, 2), np.float32))
    pipe.flush()
    se.deploy("q", SQL)
    with pytest.raises(ValueError, match="rejected atomically"):
        se.insert("events", [ka, kb], [10.0, 200.0],
                  np.ones((2, 2), np.float32))
    pipe.flush()
    fr = se.request("q", [kb], [500.0])
    assert fr.status.tolist() == [STATUS_UNKNOWN_KEY]  # nothing staged
    # a fully-valid batch commits on every involved shard
    se.insert("events", [ka, kb], [300.0, 300.0],
              np.ones((2, 2), np.float32))
    pipe.flush()
    fr = se.request("q", [ka, kb], [500.0, 500.0])
    assert fr.status.tolist() == [STATUS_OK, STATUS_OK]
    assert fr.columns["c"].tolist() == [2.0, 1.0]
    se.close()


def test_proc_killed_worker_shed_respawn_recover():
    """SIGKILL one worker mid-service: in-flight and subsequent batches
    for its keys degrade (stale tier, DESIGN.md §12) or shed whole-batch
    — worker_down, no hung futures, no raw exceptions — the supervisor
    respawns it, replays the catalog and deployments, and serving
    resumes; lost partitioned data re-enters through the stream."""
    keys, ts, rows = _events(n=200, n_keys=8)
    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    pipe = se.attach_stream("events", flush_interval_s=0.05)
    pipe.push_batch(keys, ts, rows)
    pipe.flush()
    se.deploy("q", SQL)
    rk, rt = list(range(8)), [2000.0] * 8
    assert (se.request("q", rk, rt).status == STATUS_OK).all()

    os.kill(se.shards[1].proc.pid, signal.SIGKILL)
    time.sleep(0.05)
    t0 = time.perf_counter()
    fr = se.request("q", rk, rt)
    # answered immediately — a hung gather would eat the 120 s RPC
    # timeout here. Every request served the first OK batch, so the
    # stale tier covers the dead shard's keys: the ladder answers a
    # DEGRADED/OK mix; an all-SHED frame is the cold-cache fallback
    assert time.perf_counter() - t0 < 30.0
    st = set(fr.status.tolist())
    assert st <= {STATUS_OK, STATUS_DEGRADED} or st == {STATUS_SHED}

    deadline = time.time() + 90
    while time.time() < deadline:
        fr = se.request("q", rk, rt)
        if set(fr.status.tolist()) <= {STATUS_OK, STATUS_UNKNOWN_KEY}:
            break
        time.sleep(0.1)
    assert se.worker_restarts == 1
    # respawned shard serves; its keys are UNKNOWN until re-ingest
    assert set(fr.status.tolist()) <= {STATUS_OK, STATUS_UNKNOWN_KEY}
    m = se.resources.metrics()
    assert m["served_degraded"] >= 1 or m["shed_worker_down"] >= 1
    pipe.push_batch(keys, ts + 3000.0, rows)
    pipe.flush()
    fr = se.request("q", rk, [9000.0] * 8)
    assert (fr.status == STATUS_OK).all()
    se.close()


def test_proc_elastic_add_shard():
    """add_shard spawns a NEW subprocess, replays the catalog into it,
    seeds replicas, rebuilds deployments, and migrates key ranges —
    outputs identical before/after."""
    keys, ts, rows = _events()
    se = ShardedEngine(ShardConfig(n_shards=2), backend="process")
    se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
    se.create_table(DIM, max_keys=16, capacity=16, bucket_size=8,
                    replicate=True)
    drow = np.stack([np.arange(4) * 0.1, np.arange(4) * 1.0],
                    -1).astype(np.float32)
    se.insert("dim", list(range(4)), [1.0] * 4, drow)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("jq", _join_query())
    rk, rt = list(range(16)), [2000.0] * 16
    before = se.request("jq", rk, rt, rows=rows[:16])
    assert (before.status == STATUS_OK).all()

    s_new = se.add_shard()
    assert se.n_shards == 3
    after = se.request("jq", rk, rt, rows=rows[:16])
    assert np.array_equal(before.status, after.status)
    for n in before.columns:
        assert np.array_equal(np.asarray(before[n]),
                              np.asarray(after[n])), n
    # the new worker actually owns traffic (ring moved ~1/3 of the space)
    counts = se._routing.shard_counts()
    assert counts.get(s_new, 0) > 0
    res = se.query_offline("jq")
    assert len(res["__version_vector"]) == 3
    se.close()


def test_proc_sigkill_during_add_shard_migration_bit_identical():
    """SIGKILL the NEW worker while add_shard's arc-batch migration is
    feeding it (A→B, B dies mid-copy): the interrupted batch retries —
    the source keeps its stale copy, ``migrate_in`` prefix-skips what
    already landed, and ``_reshard`` waits out the respawn — so
    ``add_shard`` completes and the 3-shard output is bit-identical to
    an in-process engine grown the same way without any failure."""
    import shutil
    import tempfile
    import threading

    keys, ts, rows = _events(n=300, n_keys=16)
    wal_dir = tempfile.mkdtemp(prefix="mig-wal-")
    se = ShardedEngine(
        ShardConfig(n_shards=2, wal_dir=wal_dir, standby_workers=1,
                    migrate_batch_arcs=2),
        backend="process")
    ref = ShardedEngine(ShardConfig(n_shards=2))       # in-process twin
    try:
        for eng in (se, ref):
            eng.create_table(SCHEMA, max_keys=64, capacity=64,
                             bucket_size=8)
            pipe = eng.attach_stream("events", flush_interval_s=0.05)
            pipe.push_batch(keys, ts, rows)
            pipe.flush()
            eng.deploy("q", SQL)
        rk, rtimes = list(range(16)), [2000.0] * 16
        assert (se.request("q", rk, rtimes).status == STATUS_OK).all()

        grown = []
        def grow():
            grown.append(se.add_shard())
        th = threading.Thread(target=grow)
        th.start()
        # wait until migration has flipped >= 1 arc to the new shard —
        # we are then provably inside the arc-batch copy loop (~32
        # batches at 2 arcs/batch over 64 vnodes) — and SIGKILL it
        deadline = time.time() + 120
        killed = False
        while time.time() < deadline and not killed:
            if se._routing.shard_counts().get(2, 0) > 0:
                os.kill(se.shards[2].proc.pid, signal.SIGKILL)
                killed = True
            time.sleep(0.002)
        assert killed, "migration never started"
        th.join(timeout=180)
        assert not th.is_alive(), "add_shard hung after mid-copy kill"
        assert grown == [2] and se.n_shards == 3
        assert se.worker_restarts >= 1

        deadline = time.time() + 90
        while time.time() < deadline:
            fr = se.request("q", rk, rtimes)
            if (fr.status == STATUS_OK).all():
                break
            time.sleep(0.1)
        assert (fr.status == STATUS_OK).all()

        ref.add_shard()
        want = ref.request("q", rk, rtimes)
        for n in want.columns:
            assert np.array_equal(np.asarray(want[n]),
                                  np.asarray(fr[n])), n
        # the respawned new shard really owns traffic again
        assert se._routing.shard_counts().get(2, 0) > 0
    finally:
        se.close()
        ref.close()
        shutil.rmtree(wal_dir, ignore_errors=True)
