"""Streaming ingestion subsystem: watermark repair semantics, background
flush + snapshot isolation, TTL compaction, and online/offline consistency
over replayed streams."""
import threading
import time

import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import EventStreamConfig
from repro.featurestore.preagg import verify_preagg
from repro.featurestore.table import Table, TableSchema
from repro.streaming import (IngestPipeline, PipelineConfig,
                             RetentionPolicy, StreamBuffer, StreamSource,
                             compact_expired, online_offline_consistency)

SQL = """
SELECT SUM(amount) OVER w AS s,
       COUNT(amount) OVER w AS c,
       MAX(amount) OVER w AS mx
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 20 PRECEDING AND CURRENT ROW)
"""


def schema3():
    return TableSchema("events", key_col="user", ts_col="ts",
                       value_cols=("amount", "lat", "lon"))


def source(n=400, n_keys=8, seed=0):
    return StreamSource.from_config(EventStreamConfig(
        n_events=n, n_keys=n_keys, n_features=3, seed=seed))


# ---------------------------------------------------------------- buffer
def test_buffer_in_order_passthrough():
    b = StreamBuffer(lateness=0.0)
    for i in range(10):
        assert b.push("a", float(i), np.asarray([i], np.float32))
    keys, ts, rows = b.ready()
    assert keys == ["a"] * 10
    np.testing.assert_array_equal(ts, np.arange(10, dtype=np.float32))
    assert b.stats.dropped_late == 0
    assert b.stats.reordered == 0


def test_buffer_repairs_within_watermark():
    """Disorder smaller than the lateness window is sorted away."""
    b = StreamBuffer(lateness=5.0)
    order = [3.0, 1.0, 2.0, 0.5, 4.0]
    for t in order:
        assert b.push("a", t, np.asarray([t], np.float32))
    # watermark = 4.0 - 5.0 < all events: nothing releasable yet
    k, ts, _ = b.ready()
    assert len(k) == 0
    b.push("a", 9.5, np.asarray([9.5], np.float32))   # wm -> 4.5
    k, ts, rows = b.ready()
    assert list(ts) == sorted(ts)                      # repaired
    assert list(ts) == [0.5, 1.0, 2.0, 3.0, 4.0]
    assert b.stats.reordered > 0
    assert b.stats.dropped_late == 0


def test_buffer_drops_beyond_watermark():
    """An event older than the released frontier is unrepairable."""
    b = StreamBuffer(lateness=1.0)
    b.push("a", 10.0, np.zeros(1, np.float32))
    b.push("a", 12.0, np.zeros(1, np.float32))
    k, ts, _ = b.ready()                   # releases ts <= 11.0 -> [10.0]
    assert list(ts) == [10.0]
    assert not b.push("a", 9.0, np.zeros(1, np.float32))   # < frontier
    assert b.stats.dropped_late == 1
    # but 11.5 (> frontier, inside window) is still accepted
    assert b.push("a", 11.5, np.zeros(1, np.float32))


def test_buffer_per_key_watermarks_independent():
    b = StreamBuffer(lateness=1.0)
    b.push("a", 100.0, np.zeros(1, np.float32))
    b.push("a", 102.0, np.zeros(1, np.float32))
    b.push("b", 1.0, np.zeros(1, np.float32))
    b.push("b", 3.0, np.zeros(1, np.float32))
    k, ts, _ = b.ready()
    # a's watermark is 101 (hwm 102 - 1), b's is 2 — each key releases
    # against its own clock; the newest event of a key always stays
    # staged until a later event (or flush_all) moves the watermark past
    assert set(zip(k, ts.tolist())) == {("a", 100.0), ("b", 1.0)}


def test_buffer_bounded_state_force_release():
    b = StreamBuffer(lateness=1e9, max_staged=8)   # nothing ever final
    for i in range(16):
        b.push("a", float(i), np.zeros(1, np.float32))
    k, ts, _ = b.ready()
    assert len(k) >= 8                     # oldest forced through
    assert list(ts) == sorted(ts)


# -------------------------------------------------- out-of-order == sorted
def test_disordered_stream_features_equal_sorted_ingest():
    """Events shuffled within the reorder window produce IDENTICAL
    features to a cleanly sorted ingest (the repair guarantee)."""
    src = source(400)
    flags = OptFlags(assume_latest=False)

    eng_sorted = Engine(flags)
    t_sorted = eng_sorted.create_table(schema3(), max_keys=16,
                                       capacity=128, bucket_size=16)
    src.backfill(t_sorted)
    eng_sorted.deploy("f", SQL)

    eng_stream = Engine(flags)
    _, pipe = eng_stream.create_stream(schema3(), max_keys=16,
                                       capacity=128, bucket_size=16,
                                       lateness=2.0,
                                       flush_interval_s=0.001)
    disordered = src.with_disorder(jitter=1.5, seed=3)
    disordered.replay(pipe, batch_size=32)
    pipe.flush()
    eng_stream.deploy("f", SQL)
    assert pipe.metrics()["reordered"] > 0          # disorder happened
    assert pipe.metrics()["dropped_late"] == 0      # all inside window

    off_a = eng_sorted.query_offline("f")
    off_b = eng_stream.query_offline("f")
    oa = np.lexsort((off_a["__ts"], off_a["__key"]))
    ob = np.lexsort((off_b["__ts"], off_b["__key"]))
    for name in ("s", "c", "mx"):
        np.testing.assert_allclose(off_a[name][oa], off_b[name][ob],
                                   rtol=1e-6, atol=1e-6, err_msg=name)
    eng_sorted.close()
    eng_stream.close()


def test_stream_replay_online_offline_consistency():
    """Point-in-time parity of the two execution modes survives streaming
    delivery (paper's training-serving-skew guarantee)."""
    eng = Engine(OptFlags(assume_latest=False))
    _, pipe = eng.create_stream(schema3(), max_keys=16, capacity=128,
                                bucket_size=16, lateness=2.0)
    source(300).with_disorder(jitter=1.0, seed=5).replay(pipe,
                                                         batch_size=64)
    pipe.flush()
    eng.deploy("f", SQL)
    ok, errs = online_offline_consistency(eng, "f")
    assert ok, errs
    eng.close()


# ------------------------------------------------------ background flusher
def test_pipeline_background_flush_without_explicit_flush():
    """Pushes drain on their own once past the watermark."""
    t = Table(schema3(), max_keys=8, capacity=64, bucket_size=8)
    pipe = IngestPipeline(t, PipelineConfig(lateness=0.0,
                                            flush_interval_s=0.001))
    for i in range(20):
        pipe.push("u", float(i), np.asarray([i, 0, 0], np.float32))
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        if int(np.asarray(t.state.total).sum()) == 20:
            break
        time.sleep(0.01)
    assert int(np.asarray(t.state.total).sum()) == 20
    assert pipe.last_error is None
    assert pipe.metrics()["flushes"] >= 1
    pipe.close()


def test_pipeline_push_does_not_block_on_flush():
    """push latency stays microseconds-scale even while ingest runs."""
    t = Table(schema3(), max_keys=64, capacity=1024, bucket_size=64)
    pipe = IngestPipeline(t, PipelineConfig(lateness=0.0,
                                            flush_interval_s=0.0))
    src = source(2000, n_keys=32)
    lat = []
    for i in range(len(src)):
        t0 = time.perf_counter()
        pipe.push(int(src.keys[i]), float(src.ts[i]), src.rows[i])
        lat.append(time.perf_counter() - t0)
    pipe.flush()
    assert pipe.last_error is None
    # p99 stage latency well under a single jitted ingest dispatch
    assert float(np.percentile(lat, 99)) < 0.01
    pipe.close()


def test_snapshot_isolation_under_concurrent_flush():
    """A reader's captured snapshot stays internally consistent (and
    readable) while flushes publish new versions concurrently."""
    t = Table(schema3(), max_keys=8, capacity=256, bucket_size=16)
    pipe = IngestPipeline(t, PipelineConfig(lateness=0.0,
                                            flush_interval_s=0.0))
    src = source(1500, n_keys=8, seed=9)
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            snap = t.snapshot()
            tot = np.asarray(snap.state.total)     # device read of v
            ts = np.asarray(snap.state.ts)
            # consistency inside one snapshot: per key, the number of
            # live (non-sentinel) ts slots matches its total
            for k in range(ts.shape[0]):
                n_live = int((ts[k] > -1e38).sum())
                if n_live != min(int(tot[k]), ts.shape[1]):
                    errors.append((snap.version, k, n_live, int(tot[k])))
            if snap.preagg is not None:
                np.asarray(snap.preagg.sum)        # must not be donated

    th = threading.Thread(target=reader, daemon=True)
    th.start()
    src.replay(pipe, batch_size=64)
    pipe.flush()
    stop.set()
    th.join(timeout=5.0)
    assert pipe.last_error is None
    assert not errors, errors[:5]
    assert t.version > 0
    pipe.close()


def test_versions_monotone_and_swap_atomic():
    t = Table(schema3(), max_keys=4, capacity=64, bucket_size=8)
    v0 = t.version
    t.insert(["a"], [1.0], np.zeros((1, 3), np.float32))
    assert t.version == v0 + 1
    snap = t.snapshot()
    t.insert(["a"], [2.0], np.zeros((1, 3), np.float32))
    assert t.snapshot().version == snap.version + 1


# ----------------------------------------------------------- TTL retention
def test_ttl_compaction_after_wraparound_keeps_preagg_valid():
    """Fill past capacity (ring wraparound), compact by TTL, and verify
    the rebuilt preagg tier against the raw state."""
    t = Table(schema3(), max_keys=4, capacity=32, bucket_size=8)
    n = 100                                        # 100 > 32: wraps
    ts = np.arange(n, dtype=np.float32)
    rows = np.random.default_rng(0).normal(
        0, 1, (n, 3)).astype(np.float32)
    t.insert(["u"] * n, ts.tolist(), rows)

    snap = t.snapshot()
    new_state, new_preagg, dropped = compact_expired(
        snap.state, cutoff=80.0, bucket_size=t.bucket_size)
    # live events were ts 68..99 (last 32); cutoff 80 keeps 80..99
    assert dropped == 12
    assert int(np.asarray(new_state.total)[0]) == 20
    kept_ts = np.asarray(new_state.ts)[0, :20]
    np.testing.assert_array_equal(kept_ts,
                                  np.arange(80, 100, dtype=np.float32))
    ok, err = verify_preagg(new_state, new_preagg,
                            bucket_size=t.bucket_size)
    assert ok, err
    # compaction never mutates the source snapshot
    assert int(np.asarray(snap.state.total)[0]) == 100


def test_pipeline_retention_hook_drops_expired():
    t = Table(schema3(), max_keys=4, capacity=64, bucket_size=8)
    pipe = IngestPipeline(t, PipelineConfig(
        lateness=0.0, flush_interval_s=0.0,
        retention=RetentionPolicy(ttl=10.0, every_n_flushes=1)))
    for i in range(40):
        pipe.push("u", float(i), np.asarray([i, 0, 0], np.float32))
    pipe.flush()
    m = pipe.metrics()
    assert pipe.last_error is None
    assert m["ttl_dropped"] > 0
    live_ts = np.asarray(t.state.ts)[0]
    live_ts = live_ts[live_ts > -1e38]
    assert live_ts.min() >= 39.0 - 10.0            # event clock - ttl
    ok, err = verify_preagg(t.state, t.preagg, bucket_size=8)
    assert ok, err
    pipe.close()


# ------------------------------------------------------------ engine API
def test_engine_insert_routes_through_attached_stream():
    eng = Engine(OptFlags())
    _, pipe = eng.create_stream(schema3(), max_keys=8, capacity=64,
                                bucket_size=8, lateness=0.5)
    src = source(60, n_keys=4)
    order = np.argsort(src.ts, kind="stable")
    eng.insert("events", src.keys[order].tolist(),
               src.ts[order].tolist(), src.rows[order])
    assert int(np.asarray(eng.tables["events"].state.total).sum()) == 60
    assert pipe.metrics()["events_flushed"] == 60
    eng.close()


def test_engine_insert_is_atomic_on_late_events():
    """A sync insert containing one unrepairably-late event stages
    NOTHING (all-or-nothing), so a corrected retry cannot double-ingest."""
    eng = Engine(OptFlags())
    t, pipe = eng.create_stream(schema3(), max_keys=8, capacity=64,
                                bucket_size=8, lateness=0.5)
    eng.insert("events", ["u", "u"], [10.0, 12.0],
               np.ones((2, 3), np.float32))
    staged_before = pipe.buffer.n_staged
    with pytest.raises(ValueError, match="rejected atomically"):
        eng.insert("events", ["u", "u"], [13.0, 5.0],   # 5.0 < frontier
                   np.ones((2, 3), np.float32))
    assert pipe.buffer.n_staged == staged_before        # nothing staged
    eng.insert("events", ["u", "u"], [13.0, 14.0],      # corrected retry
               np.ones((2, 3), np.float32))
    assert int(np.asarray(t.state.total).sum()) == 4    # no double-ingest
    eng.close()


def test_attach_to_nonempty_table_seeds_frontier():
    """An event older than pre-attach history must be rejected at push
    time — not accepted and then wedge the flusher in a retry loop."""
    eng = Engine(OptFlags())
    t = eng.create_table(schema3(), max_keys=8, capacity=64, bucket_size=8)
    t.insert(["a"], [10.0], np.ones((1, 3), np.float32))
    pipe = eng.attach_stream("events", lateness=0.0,
                             flush_interval_s=0.001)
    assert not pipe.push("a", 5.0, np.ones(3, np.float32))   # stale
    assert pipe.push("a", 11.0, np.ones(3, np.float32))      # live
    pipe.flush()
    m = pipe.metrics()
    assert m["dropped_late"] == 1 and m["errors"] == 0
    assert int(np.asarray(t.state.total).sum()) == 2
    assert pipe.last_error is None
    eng.close()


def test_non_finite_timestamp_rejected_loudly():
    b = StreamBuffer(lateness=1.0)
    with pytest.raises(ValueError, match="non-finite"):
        b.push("a", float("nan"), np.zeros(1, np.float32))
    assert b.n_staged == 0
    assert not b.has_ready()                             # no poisoned state


def test_attach_stream_validation():
    eng = Engine(OptFlags())
    eng.create_table(schema3(), max_keys=8, capacity=64, bucket_size=8)
    eng.attach_stream("events")
    with pytest.raises(ValueError, match="already has a stream"):
        eng.attach_stream("events")
    with pytest.raises(KeyError):
        eng.attach_stream("nope")
    eng.close()


def test_feature_server_ingest_and_request():
    from repro.serving.server import FeatureServer
    eng = Engine(OptFlags())
    _, pipe = eng.create_stream(schema3(), max_keys=8, capacity=64,
                                bucket_size=8, lateness=0.0,
                                flush_interval_s=0.001)
    src = source(80, n_keys=4)
    order = np.argsort(src.ts, kind="stable")
    eng.insert("events", src.keys[order].tolist(),
               src.ts[order].tolist(), src.rows[order])
    eng.deploy("f", SQL)
    srv = FeatureServer(eng, "f")
    assert srv.pipeline is pipe
    assert srv.ingest(int(src.keys[0]), float(src.ts.max()) + 1.0,
                      np.asarray([5.0, 0, 0], np.float32))
    pipe.flush()
    out = srv.request(int(src.keys[0]), float(src.ts.max()) + 2.0)
    assert float(out["c"]) >= 1.0
    srv.close()
    eng.close()


def test_handle_serves_across_republish_during_swap():
    """A hot-swap redeploy while the stream republishes the table: both
    versions read consistent snapshots, requests never fail, and the
    pipeline context-manager close is idempotent."""
    eng = Engine(OptFlags())
    _, pipe = eng.create_stream(schema3(), max_keys=8, capacity=64,
                                bucket_size=8, lateness=0.0,
                                flush_interval_s=0.001)
    src = source(120, n_keys=4)
    half = len(src.keys) // 2
    with pipe:
        pipe.push_batch(src.keys[:half].tolist(), src.ts[:half],
                        src.rows[:half])
        pipe.flush()
        v_before = pipe.version
        h1 = eng.deploy("q", SQL)
        rk = [src.keys[0]]
        rt = [float(src.ts.max()) + 1.0]
        f1 = h1.request(rk, rt)
        assert f1.version == 1 and f1.table_version >= v_before

        # ingest the second half (republishes) while redeploying
        pipe.push_batch(src.keys[half:].tolist(), src.ts[half:],
                        src.rows[half:])
        h2 = eng.deploy("q", SQL.replace("20 PRECEDING", "5 PRECEDING"))
        pipe.flush()
        assert pipe.version > v_before             # table republished
        f2 = h2.request(rk, rt)
        assert f2.version == 2 and f2.table_version >= pipe.version
        # the retired handle still serves (pinned/shadow traffic) and
        # reads the CURRENT snapshot, not a stale one
        f1b = h1.request(rk, rt)
        assert f1b.version == 1
        assert f1b.table_version == f2.table_version
        assert float(f1b["c"][0]) >= float(f1["c"][0])
    pipe.close()                                   # idempotent second close
    eng.close()
