"""Checkpoint manager: atomicity, crc validation, retention, async,
restore-with-reshard."""
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(step):
    return {"params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.arange(4.0) + step},
            "opt": {"m": jnp.zeros((4, 4)), "count": jnp.asarray(step)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retain=3, async_save=False)
    mgr.save(5, tree(5), extra={"note": "x"})
    got, meta = mgr.restore(None, tree(0))
    assert meta.step == 5 and meta.extra["note"] == "x"
    np.testing.assert_allclose(got["params"]["w"], np.full((4, 4), 5.0))
    assert int(got["opt"]["count"]) == 5


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retain=2, async_save=True)
    for s in (1, 2, 3):
        mgr.save(s, tree(s))
    mgr.wait()
    assert mgr.all_steps() == [2, 3]            # retention


def test_retention_with_archive(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retain=1, archive_every=2,
                            async_save=False)
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, tree(s))
    assert mgr.all_steps() == [2, 4, 5]         # archives 2,4 + newest 5


def test_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, tree(1))
    d = os.path.join(str(tmp_path), "step_0000000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    arr = arr.reshape(-1)
    arr = arr.copy()
    arr.flat[0] += 1.0
    np.save(os.path.join(d, victim), arr)
    with pytest.raises(IOError, match="crc"):
        mgr.restore(1, tree(0))
    # validation can be bypassed explicitly (forensics path)
    got, _ = mgr.restore(1, tree(0), validate=False)


def test_atomic_publish_no_partial_checkpoint(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(os.path.join(str(tmp_path), "tmp.9.zzz"))
    assert mgr.all_steps() == []
    mgr.save(1, tree(1))
    assert mgr.all_steps() == [1]


def test_restore_resharded_on_local_mesh(tmp_path):
    from repro.checkpoint.reshard import restore_resharded
    from repro.launch.mesh import make_local_mesh
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = {"mlp": {"wi": jnp.ones((8, 16)), "wo": jnp.ones((16, 8))}}
    mgr.save(3, t)
    mesh = make_local_mesh()
    placed, meta = restore_resharded(mgr, None, t, mesh)
    assert meta.step == 3
    np.testing.assert_allclose(np.asarray(placed["mlp"]["wi"]),
                               np.ones((8, 16)))
    # placed arrays carry shardings from the rule table
    assert placed["mlp"]["wi"].sharding is not None


def test_leaf_slice_bytes_contiguous():
    from repro.checkpoint.reshard import leaf_slice_bytes
    off, ln = leaf_slice_bytes((8, 4), np.float32, axis=0, shard=1,
                               n_shards=2)
    assert off == 4 * 4 * 4 and ln == 4 * 4 * 4
    with pytest.raises(ValueError):
        leaf_slice_bytes((8, 4), np.float32, axis=1, shard=0, n_shards=2)
