"""Paper Figure 1: QPS + latency, OpenMLDB vs emulated engine baselines.

Paper claims (absolute numbers are hardware-specific; we validate the
ORDERING and the ~10x+ ratio): OpenMLDB ~12.5-17k QPS at ~1-4 ms;
best competitor <1-8k QPS at 20-120 ms.
"""
from __future__ import annotations

from repro.core.baselines import PROFILES, BaselineRunner, make_engine
from repro.data.synthetic import EventStreamConfig, generate_events

from benchmarks.common import (FEATURE_SQL, N_EVENTS, N_KEYS, QUICK,
                               Reporter, replay)

# row_interpreter is ~1000x slower per request; keep its sample small
BUDGET = ({"openmldb": (64, 6), "microbatch": (64, 3),
           "columnar_scan": (64, 3), "row_interpreter": (16, 1)}
          if QUICK else
          {"openmldb": (256, 30), "microbatch": (256, 8),
           "columnar_scan": (256, 12), "row_interpreter": (64, 2)})


def run(rep: Reporter) -> dict:
    results = {}
    for profile in ("openmldb", "microbatch", "columnar_scan",
                    "row_interpreter"):
        eng = make_engine(profile)
        from repro.featurestore.table import TableSchema
        schema = TableSchema("events", key_col="user", ts_col="ts",
                             value_cols=("amount", "lat", "lon", "cat",
                                         "drift", "drift2"))
        eng.create_table(schema, max_keys=N_KEYS, capacity=1024,
                         bucket_size=64)
        data = generate_events(EventStreamConfig(
            n_events=N_EVENTS, n_keys=N_KEYS, n_features=6))
        keys, ts, rows = data
        eng.insert("events", keys.tolist(), ts.tolist(), rows)
        eng.deploy("bench", FEATURE_SQL)
        runner = BaselineRunner(eng, "bench", profile)
        batch, nb = BUDGET[profile]
        r = replay(eng, data, serve=lambda ks, rts: runner.serve_batch(
            ks.tolist(), rts.tolist()), batch=batch, n_batches=nb)
        results[profile] = r
        rep.add(f"fig1/{profile}", 1e6 / r["qps"], qps=round(r["qps"], 1),
                p50_req_ms=round(r["p50_req_ms"], 4),
                p50_batch_ms=round(r["p50_batch_ms"], 3))
        eng.close()
    ours = results["openmldb"]["qps"]
    best_other = max(r["qps"] for k, r in results.items()
                     if k != "openmldb")
    rep.add("fig1/speedup_vs_best_baseline", 0.0,
            ratio=round(ours / best_other, 2),
            paper_claim="10-23x vs generic engines")
    return results
