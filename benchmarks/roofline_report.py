"""Aggregate dry-run cell JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        --dir experiments/dryrun --md

Reads every ``<arch>__<shape>__<mesh>.json`` produced by launch/dryrun.py
and emits (a) the §Dry-run compile/memory table, (b) the §Roofline terms
table (single-pod cells), (c) the hillclimb candidate ranking.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str) -> List[Dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def sort_key(c):
    return (c["arch"], SHAPE_ORDER.index(c["shape"]), c["mesh"])


def dryrun_table(cells: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile | args/dev | temp/dev | out/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=sort_key):
        if c["status"] == "skip":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"SKIP ({c['skip_reason'][:40]}…) | | | | |")
            continue
        if c["status"] == "fail":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"FAIL {c.get('error', '')[:60]} | | | | |")
            continue
        m = c["memory"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok | "
            f"{c['compile_s']:.0f}s | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | {fmt_bytes(m['output_bytes'])} |")
    return "\n".join(rows)


HBM_BW = 819e9


def mem_efficiency(c: Dict) -> float:
    """Ideal bytes (touch every resident argument once, twice for train
    params+opt which are also written) vs the measured HLO bytes."""
    args = c["memory"]["argument_bytes"]
    mult = 2.0 if c["kind"] == "train" else 1.0
    ideal_s = mult * args / HBM_BW
    return min(ideal_s / c["memory_s"], 1.0) if c["memory_s"] else 0.0


def roofline_table(cells: List[Dict]) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful | mem-eff | roofline-frac | bound-step |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=sort_key):
        if c["mesh"] != "pod" or c["status"] != "ok":
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(c['compute_s'])} | "
            f"{fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} | "
            f"**{c['dominant']}** | {c['useful_flop_ratio']:.3f} | "
            f"{mem_efficiency(c):.3f} | "
            f"{c['roofline_frac']:.4f} | {fmt_s(c['step_s_est'])} |")
    return "\n".join(rows)


def candidates(cells: List[Dict]) -> str:
    ok = [c for c in cells if c["mesh"] == "pod" and c["status"] == "ok"]
    worst = sorted(ok, key=lambda c: c["roofline_frac"])[:5]
    coll = sorted(ok, key=lambda c: -(c["collective_s"]
                                      / max(c["step_s_est"], 1e-12)))[:5]
    out = ["worst roofline fraction:"]
    out += [f"  {c['arch']} × {c['shape']}: frac={c['roofline_frac']:.4f} "
            f"dom={c['dominant']}" for c in worst]
    out.append("most collective-bound:")
    out += [f"  {c['arch']} × {c['shape']}: coll share="
            f"{c['collective_s'] / max(c['step_s_est'], 1e-12):.2f}"
            for c in coll]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    cells = load(args.dir)
    n_ok = sum(1 for c in cells if c["status"] == "ok")
    n_skip = sum(1 for c in cells if c["status"] == "skip")
    n_fail = sum(1 for c in cells if c["status"] == "fail")
    print(f"# cells: {n_ok} ok / {n_skip} skip / {n_fail} fail\n")
    print("## Dry-run (both meshes)\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(cells))
    print("\n## Hillclimb candidates\n")
    print(candidates(cells))
    return 0


if __name__ == "__main__":
    sys.exit(main())
