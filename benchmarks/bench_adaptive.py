"""Adaptive knob control vs static serving config (DESIGN.md §10).

Workload: alternating **burst / lull** arrival phases against a
``FeatureServer``. Bursts (many concurrent requests) coalesce into full
batches regardless of the batching deadline; lulls (lone requests with
inter-arrival gaps longer than ``max_delay_s``) pin each request's
latency to the *full* deadline — the batcher waits out ``max_delay_s``
hoping for company that never arrives. A static config tuned for burst
throughput therefore pays its whole delay budget as pure lull latency.

Two drift-bracketed runs over identical seeded arrivals:

* ``static``   — fixed ``max_delay_s`` for the whole run (measured
  before AND after the adaptive run, so machine drift can't fake a win);
* ``adaptive`` — a :class:`repro.control.KnobController` observes each
  round's client-side p99 and AIMD-backs the batching deadline off
  through the live ``DynamicBatcher.reconfigure`` knob, exactly as the
  ControlPlane applies it.

Headline: steady-state (final-half) p99 — the controller must beat the
better of the two static brackets, or shed strictly fewer requests at
equal p99. The controller's decision log is replayed
(``KnobController.replay``) and checked bit-for-bit: the adaptation is
reproducible from its seeded log, not an artifact of run-time noise.

Emits ``experiments/BENCH_adaptive.json`` (quick mode writes an ignored
``bench_adaptive_quick.json`` so CI smoke never clobbers the committed
trajectory file).
"""
from __future__ import annotations

import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List

import numpy as np

from benchmarks.common import QUICK, Reporter, build_engine
from repro.control import KnobConfig, KnobController, LoadObservation
from repro.serving.batcher import BatcherConfig
from repro.serving.server import FeatureServer, ServerConfig

OUT_PATH = os.path.join(
    "experiments",
    "bench_adaptive_quick.json" if QUICK else "BENCH_adaptive.json")

STATIC_DELAY_S = 0.004            # burst-tuned deadline the lulls pay for
N_ROUNDS = 6 if QUICK else 14     # one round = burst phase + lull phase
BURST_N = 16 if QUICK else 48     # concurrent requests per burst
LULL_N = 6 if QUICK else 12       # lone requests per lull
LULL_GAP_S = 0.006                # > STATIC_DELAY_S: no coalescing ever
SEED = 17

KNOB_CFG = KnobConfig(
    target_p99_s=0.002,           # the SLO the lulls violate at 4ms delay
    hysteresis_ticks=2,           # one noisy round never moves the knob
    backoff=0.5,
    min_delay_s=0.0002,
    max_delay_s=STATIC_DELAY_S,
)


def _pcts(lats_ms: List[float]) -> Dict[str, float]:
    a = np.asarray(lats_ms)
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()), "n": int(a.size)}


def _run_mode(eng, keys, base_ts, controller=None) -> Dict[str, object]:
    """One full burst/lull run. ``controller=None`` = static knobs;
    otherwise the controller observes each round's client p99 and its
    decisions are applied to the live batcher (the ControlPlane's
    ``delay_s`` mapping)."""
    rng = np.random.default_rng(SEED)
    server = FeatureServer(eng, "bench", ServerConfig(
        batcher=BatcherConfig(max_batch=64, max_delay_s=STATIC_DELAY_S),
        warm_buckets=(1, 2, 4, 8, 16, 32, 64)))
    rounds: List[Dict[str, object]] = []
    shed = 0
    try:
        with ThreadPoolExecutor(max_workers=8) as pool:
            for r in range(N_ROUNDS):
                lats: List[float] = []

                def one(key, ts):
                    t0 = time.perf_counter()
                    server.request(key, ts)
                    return (time.perf_counter() - t0) * 1e3

                # burst: concurrent arrivals coalesce into full batches
                burst = [(int(rng.choice(keys)), base_ts + r)
                         for _ in range(BURST_N)]
                lats += list(pool.map(lambda a: one(*a), burst))
                # lull: lone arrivals, gap > max_delay -> no coalescing
                for _ in range(LULL_N):
                    time.sleep(LULL_GAP_S)
                    lats.append(one(int(rng.choice(keys)), base_ts + r))

                p = _pcts(lats)
                entry = {"round": r, **p,
                         "delay_s": server.batcher.cfg.max_delay_s}
                if controller is not None:
                    obs = LoadObservation(
                        p99_s=p["p99_ms"] / 1e3,
                        queue_depth=server.batcher.queue_depth(),
                        shed=0, rejected=0, requests=len(lats))
                    for d in controller.step(obs):
                        if d.knob == "delay_s":       # the managed knob
                            server.batcher.reconfigure(
                                max_delay_s=float(d.new))
                    entry["decisions"] = len(controller.log[-1]["decisions"])
                rounds.append(entry)
        shed = server.batcher.stats["expired"] + server.batcher.stats[
            "rejected"]
    finally:
        server.close()
    # steady state = final half, after the controller had time to converge
    steady = rounds[len(rounds) // 2:]
    lat_all = {"p50_ms": float(np.median([e["p50_ms"] for e in steady])),
               "p99_ms": float(np.median([e["p99_ms"] for e in steady]))}
    n_total = sum(e["n"] for e in rounds)
    return {"rounds": rounds, "steady": lat_all, "shed": shed,
            "n_requests": n_total,
            "final_delay_s": rounds[-1]["delay_s"]}


def run(rep: Reporter) -> dict:
    eng, data = build_engine()
    keys, ts, _ = data
    base_ts = float(ts.max()) + 1.0

    # drift bracket: static, adaptive, static again
    static_a = _run_mode(eng, keys, base_ts)
    controller = KnobController(KNOB_CFG, seed=SEED,
                                delay_s=STATIC_DELAY_S)
    adaptive = _run_mode(eng, keys, base_ts, controller=controller)
    static_b = _run_mode(eng, keys, base_ts)

    # the controller must actually have acted, and its decision sequence
    # must replay bit-for-bit from the seeded log (ISSUE §10 determinism)
    n_decisions = sum(len(e["decisions"]) for e in controller.log)
    if n_decisions == 0:
        raise RuntimeError("adaptive run made zero knob decisions — the "
                           "controller is not wired to the load signal")
    replayed = KnobController.replay(KNOB_CFG, SEED,
                                     {"delay_s": STATIC_DELAY_S},
                                     controller.log)
    if replayed.log != controller.log:
        raise RuntimeError("knob decision log did not replay identically")

    best_static_p99 = min(static_a["steady"]["p99_ms"],
                          static_b["steady"]["p99_ms"])
    margin = best_static_p99 / adaptive["steady"]["p99_ms"]
    wins = (adaptive["steady"]["p99_ms"] < best_static_p99
            or (adaptive["shed"] < min(static_a["shed"], static_b["shed"])))
    if not wins:
        raise RuntimeError(
            f"adaptive tripwire: steady p99 {adaptive['steady']['p99_ms']:.2f}"
            f"ms vs best static {best_static_p99:.2f}ms and no shed win — "
            f"the controller failed to beat the static config")

    res = {
        "quick": QUICK,
        "adaptive": {"qps": 0.0, **adaptive["steady"],
                     "shed": adaptive["shed"],
                     "final_delay_s": adaptive["final_delay_s"],
                     "rounds": adaptive["rounds"]},
        "static": {"bracket_a": static_a["steady"],
                   "bracket_b": static_b["steady"],
                   "shed": static_a["shed"] + static_b["shed"],
                   "delay_s": STATIC_DELAY_S},
        "margin_p99": round(margin, 3),
        "n_decisions": n_decisions,
        "replay_identical": True,
        "decision_log": controller.log,
        "knob_cfg": {"target_p99_s": KNOB_CFG.target_p99_s,
                     "backoff": KNOB_CFG.backoff,
                     "hysteresis_ticks": KNOB_CFG.hysteresis_ticks,
                     "min_delay_s": KNOB_CFG.min_delay_s},
        "seed": SEED,
    }
    # qps headline (for BENCH_summary): steady-state request rate of the
    # adaptive run, lull sleep time included (it is part of the arrivals)
    wall = sum(LULL_N * LULL_GAP_S for _ in range(N_ROUNDS))
    res["adaptive"]["qps"] = round(
        adaptive["n_requests"] / max(wall, 1e-9), 1)

    rep.add("adaptive/static_p99", best_static_p99 * 1e3,
            **{"p99_ms": best_static_p99})
    rep.add("adaptive/controller_p99",
            adaptive["steady"]["p99_ms"] * 1e3,
            **adaptive["steady"], margin=res["margin_p99"],
            decisions=n_decisions)

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=1)
    eng.close()
    return {k: v for k, v in res.items() if k != "decision_log"}


if __name__ == "__main__":
    r = Reporter()
    out = run(r)
    print(r.emit())
    print(json.dumps({k: v for k, v in out.items() if k != "adaptive"}
                     | {"adaptive_steady": out["adaptive"]},
                     indent=1, default=str))
