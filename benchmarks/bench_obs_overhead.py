"""Observability overhead: what tracing + profiling cost the hot path.

The obs tier (DESIGN.md §13) is compiled into every serving tier — the
question is what it costs when OFF (the zero-sampling fast path: one
float compare per call site), when fully ON (sample 1.0: every request
records a full span tree and the profiler attributes every batch), and
at a production-ish 1% sample.

Measurement: the three phases are INTERLEAVED over several rounds
(off / full / 1% per round, same warmed engine) and the reported
overhead is the MEDIAN of the per-round p50 ratios — a single
off-vs-on bracket is useless on a 2-core CI host whose phase-to-phase
drift (±10%) exceeds the effect being measured.

Acceptance (ISSUE 9): full tracing stays within ~5% of the untraced
p50. The recorded ``within_5pct`` is the acceptance view; the hard
tripwire only fires beyond 2x (a structural regression — e.g. span
recording landing on the per-row path — not host noise).

Also times the export surfaces (Prometheus render, JSONL snapshot,
EXPLAIN ANALYZE) off the serving path. Emits
``experiments/BENCH_obs.json`` (quick mode writes to an ignored
``_quick`` path so CI smoke runs never clobber the committed
trajectory).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import QUICK, Reporter, build_engine, replay

from repro.core.results import RequestContext
from repro.obs.export import registry_from_engine
from repro.obs.trace import new_trace_id

N_ROUNDS = 2 if QUICK else 5
N_RENDERS = 10 if QUICK else 50

OUT_PATH = os.path.join(
    "experiments",
    "bench_obs_quick.json" if QUICK else "BENCH_obs.json")


def _phase(eng, data, sample: float) -> Dict[str, float]:
    """Replay the standard workload at one tracer sample rate; every
    request carries a trace id (the id mint itself is part of the cost
    being measured — the serving edge always pays it)."""
    eng.tracer.set_sample_rate(sample)

    def serve(ks, rts):
        ctx = RequestContext(trace_id=new_trace_id())
        return eng.request("bench", ks.tolist(), rts.tolist(), ctx=ctx)

    return replay(eng, data, serve=serve, warm=False)


def run(rep: Reporter) -> dict:
    eng, data = build_engine()
    replay(eng, data)                       # pay compiles outside rounds
    _phase(eng, data, 1.0)                  # warm the traced path too

    rounds = []
    for _ in range(N_ROUNDS):
        rounds.append({"off": _phase(eng, data, 0.0),
                       "full": _phase(eng, data, 1.0),
                       "sampled": _phase(eng, data, 0.01)})

    def med(key, field="p50_batch_ms"):
        return float(np.median([r[key][field] for r in rounds]))

    ratio_full = float(np.median(
        [r["full"]["p50_batch_ms"] / r["off"]["p50_batch_ms"]
         for r in rounds]))
    ratio_sampled = float(np.median(
        [r["sampled"]["p50_batch_ms"] / r["off"]["p50_batch_ms"]
         for r in rounds]))

    # export surfaces, off the serving path
    reg = registry_from_engine(eng)
    t0 = time.perf_counter()
    for _ in range(N_RENDERS):
        reg.render_prometheus()
    prom_us = (time.perf_counter() - t0) / N_RENDERS * 1e6
    t0 = time.perf_counter()
    for _ in range(N_RENDERS):
        reg.render_jsonl()
    jsonl_us = (time.perf_counter() - t0) / N_RENDERS * 1e6
    t0 = time.perf_counter()
    analyze = eng.explain_analyze("bench")
    analyze_us = (time.perf_counter() - t0) * 1e6
    assert "% of exec" in analyze           # profiler really populated
    tracer_counters = dict(eng.tracer.counters)
    eng.close()

    for name in ("off", "full", "sampled"):
        rep.add(f"obs/trace_{name}", 1e6 / med(name, "qps"),
                qps=round(med(name, "qps"), 1),
                p50_ms=round(med(name), 3),
                p99_ms=round(med(name, "p99_batch_ms"), 3))
    rep.add("obs/overhead", ratio_full * 100.0,
            p50_ratio_full=round(ratio_full, 4),
            p50_ratio_sampled=round(ratio_sampled, 4),
            prometheus_render_us=round(prom_us, 1),
            jsonl_render_us=round(jsonl_us, 1))

    summary = {
        "quick": QUICK,
        "n_rounds": N_ROUNDS,
        "off": {"qps": med("off", "qps"), "p50_ms": med("off"),
                "p99_ms": med("off", "p99_batch_ms")},
        "full": {"qps": med("full", "qps"), "p50_ms": med("full"),
                 "p99_ms": med("full", "p99_batch_ms")},
        "sampled_1pct": {"qps": med("sampled", "qps"),
                         "p50_ms": med("sampled"),
                         "p99_ms": med("sampled", "p99_batch_ms")},
        "p50_overhead_full": ratio_full,
        "p50_overhead_sampled": ratio_sampled,
        "within_5pct": ratio_full <= 1.05,
        "per_round_ratio_full": [
            r["full"]["p50_batch_ms"] / r["off"]["p50_batch_ms"]
            for r in rounds],
        "export": {"prometheus_render_us": prom_us,
                   "jsonl_render_us": jsonl_us,
                   "explain_analyze_us": analyze_us},
        "tracer_counters": tracer_counters,
    }
    if ratio_full > 2.0:
        raise RuntimeError(
            f"full tracing doubled the median p50 "
            f"({ratio_full:.2f}x) — span recording has landed on the "
            f"per-row path")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    return summary


if __name__ == "__main__":
    r = Reporter()
    out = run(r)
    print(r.emit())
    print(json.dumps({k: v for k, v in out.items()
                      if k not in ("tracer_counters",)}, indent=1))
