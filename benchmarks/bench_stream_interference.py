"""Stream/batch interference: the paper's "without interference" claim as
a measurable curve.

Query workload (fixed): online feature requests through ``Engine.request``
over a deployed multi-window SQL query. Ingest workload (swept): a
background thread replaying a synthetic trace through the streaming
pipeline (watermark buffer -> background flusher -> copy-on-write
publish) at

* ``off``        — no concurrent ingest (baseline),
* ``moderate``   — paced at ``MODERATE_RATE`` (~1k events/s, roughly a
  tenth of the flusher's saturation rate on the reference host),
* ``saturating`` — unpaced, as fast as the flusher drains.

Reported per rate: query QPS, p50/p99 per-batch latency, events actually
ingested during the measurement window, and the QPS degradation vs
baseline. Acceptance target: < 20% QPS loss under moderate ingest —
queries read atomically-swapped snapshots and never wait on the write
path, so the residual loss is CPU contention only.
"""
from __future__ import annotations

import threading

import numpy as np

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import EventStreamConfig
from repro.featurestore.table import TableSchema
from repro.streaming import PipelineConfig, StreamSource

from benchmarks.common import FEATURE_SQL, Reporter, replay

N_BASE_EVENTS = 8_000          # pre-loaded history (warm table)
N_STREAM_EVENTS = 60_000       # trace available to the ingest thread
N_KEYS = 256
REQ_BATCH = 256
N_REQ_BATCHES = 40
MODERATE_RATE = 1_000.0        # events/s (calibrate to the host: this is
                               # ~1/10th of the flusher's saturation rate)

# the two ingest-free baselines tightly bracket the moderate phase (the
# acceptance-critical number): averaging them cancels machine drift right
# where it matters. Saturating runs last — its degradation is expected to
# be large and drift-tolerance matters less.
RATES = (("off", 0.0), ("moderate", MODERATE_RATE),
         ("off2", 0.0), ("saturating", None))


def _build(lateness: float = 0.5):
    eng = Engine(OptFlags())
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount", "lat", "lon", "cat",
                                     "drift", "drift2"))
    # capacity ample: the stream must not evict the warm history mid-run
    eng.create_table(schema, max_keys=N_KEYS, capacity=2048,
                     bucket_size=64)
    base = StreamSource.from_config(EventStreamConfig(
        n_events=N_BASE_EVENTS, n_keys=N_KEYS, n_features=6))
    base.backfill(eng.tables["events"])
    # 20ms amortization: at moderate rates each flush carries ~40 events
    # in one jitted dispatch instead of dribbling 1-4 events per dispatch
    pipe = eng.attach_stream("events", cfg=PipelineConfig(
        lateness=lateness, flush_interval_s=0.02, max_flush_batch=2048))
    pipe.warm()          # compile all flush buckets outside the window
    eng.deploy("bench", FEATURE_SQL)
    # stream continues the timeline after the warm history
    t0 = float(base.ts.max()) + 0.01
    stream = StreamSource.from_config(EventStreamConfig(
        n_events=N_STREAM_EVENTS, n_keys=N_KEYS, n_features=6, seed=7))
    stream = StreamSource(keys=stream.keys, ts=stream.ts + t0,
                          rows=stream.rows)
    return eng, pipe, base, stream


def run(rep: Reporter) -> dict:
    # ONE engine for every phase: all phases hit the same compiled query
    # executables and the same warm table, so the only varying factor is
    # the concurrent ingest load (run-to-run recompilation would swamp
    # the interference signal otherwise).
    eng, pipe, base, stream = _build()
    # the stream timeline is consumed monotonically: one segment per
    # phase, so no phase replays event times behind the watermark
    n_seg = sum(1 for _, r in RATES if r != 0.0)
    seg_len = len(stream) // max(n_seg, 1)
    segments = [StreamSource(keys=stream.keys[i * seg_len:(i + 1) * seg_len],
                             ts=stream.ts[i * seg_len:(i + 1) * seg_len],
                             rows=stream.rows[i * seg_len:(i + 1) * seg_len])
                for i in range(n_seg)]
    results = {}
    seg_i = 0
    for label, rate in RATES:
        flushed_before = pipe.metrics()["events_flushed"]
        stop = threading.Event()
        ingest_thread = None
        if rate != 0.0:
            ingest_thread = threading.Thread(
                target=segments[seg_i].replay, args=(pipe,),
                kwargs=dict(batch_size=256, rate=rate, stop_event=stop),
                daemon=True)
            seg_i += 1
            ingest_thread.start()
        r = replay(eng, (base.keys, base.ts, base.rows),
                   batch=REQ_BATCH, n_batches=N_REQ_BATCHES)
        stop.set()
        if ingest_thread is not None:
            ingest_thread.join(timeout=10.0)
            pipe.wait_idle()
        m = pipe.metrics()
        r["events_ingested"] = int(m["events_flushed"] - flushed_before)
        r["ingest_rate_eps"] = (r["events_ingested"] / r["wall_s"]
                                if r["wall_s"] else 0.0)
        r["table_versions"] = int(m["table_version"])
        assert pipe.last_error is None, pipe.last_error
        results[label] = r
    eng.close()

    base_qps = (results["off"]["qps"] + results["off2"]["qps"]) / 2.0
    for label, _ in RATES:
        r = results[label]
        degr = 1.0 - r["qps"] / base_qps
        r["qps_degradation"] = degr
        rep.add(f"interference/{label}", 1e6 / r["qps"],
                qps=round(r["qps"], 1),
                p50_batch_ms=round(r["p50_batch_ms"], 3),
                p99_batch_ms=round(r["p99_batch_ms"], 3),
                ingest_eps=round(r["ingest_rate_eps"], 1),
                qps_degradation_pct=round(100 * degr, 2))
    ok = results["moderate"]["qps_degradation"] < 0.20
    rep.add("interference/moderate_under_20pct", 0.0, passed=bool(ok),
            claim="stream+batch without interference")
    results["pass_moderate_under_20pct"] = bool(ok)
    return results


if __name__ == "__main__":
    rep = Reporter()
    out = run(rep)
    print(rep.emit())
