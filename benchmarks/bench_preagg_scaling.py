"""Paper Eq. 2 (pre-aggregation): window-query latency vs window size.

Naive scan is O(W); the bucketed pre-aggregate tier is O(W/B + 2B).
The paper's claim: materialization makes long-window features cheap.
We sweep W and report per-request latency for both paths.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.optimizer import OptFlags

from benchmarks.common import Reporter, build_engine

SQL_TMPL = """
SELECT SUM(amount) OVER w AS s, AVG(amount) OVER w AS a,
       MAX(amount) OVER w AS mx, COUNT(amount) OVER w AS c
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN {W} PRECEDING AND CURRENT ROW)
"""

WINDOWS = (16, 64, 256, 1024, 4096)


def run(rep: Reporter) -> dict:
    out = {}
    for W in WINDOWS:
        capacity = max(2 * W, 256)
        row = {}
        for label, flags in (("preagg", OptFlags()),
                             ("naive", OptFlags(preagg=False))):
            eng, data = build_engine(
                flags, sql=SQL_TMPL.format(W=W), capacity=capacity,
                bucket_size=64, n_events=3 * capacity, n_keys=32)
            keys, ts, _ = data
            B = 64
            ks = keys[:B].tolist()
            rts = [float(ts.max()) + 1.0] * B
            eng.request("bench", ks, rts)              # warm/compile
            t0 = time.perf_counter()
            reps = 10
            for i in range(reps):
                eng.request("bench", ks, [r + i for r in rts])
            dt = (time.perf_counter() - t0) / reps
            row[label] = dt / B * 1e6                  # us per request
            impl = eng.deployments["bench"].phys.groups[0].impl
            row[f"{label}_impl"] = impl
            eng.close()
        out[W] = row
        rep.add(f"preagg/W={W}", row.get("preagg", 0.0),
                naive_us=round(row["naive"], 2),
                preagg_us=round(row["preagg"], 2),
                speedup=round(row["naive"] / row["preagg"], 2),
                impl=row["preagg_impl"])
    return out
