"""Paper Figure 2: contribution of each optimization layer.

Method (matches the paper's ablation semantics): measure QPS with ALL
optimizations on, then turn each off one at a time. The contribution of
optimization X is its share of the total speedup between the all-off and
all-on engines, attributed by leave-one-out deltas (normalised to 100%).

Paper bands: query/plan optimization ≈30-35%, caching+materialization
≈15-25%, parallel processing ≈20-25%, resource management ≈10%.
"""
from __future__ import annotations

import dataclasses

from repro.core.optimizer import OptFlags

from benchmarks.common import Reporter, build_engine, replay

# Ablation axes -> OptFlags overrides that DISABLE the optimization.
AXES = {
    "query_plan_opt": dict(query_opt=False),           # O1
    "plan_cache": dict(plan_cache=False),              # O2 (exec-plan cache)
    "preagg_materialization": dict(preagg=False),      # O3 (caching/mat.)
    "parallel_vectorized": dict(vectorized=False),     # O4
    "resource_assume_latest": dict(assume_latest=False),  # O5 (mgmt fastpath)
    "window_fusion": dict(fuse_windows=False),         # O1b (multi-window
                                                       # shared scan)
}

# FEATURE_SQL keeps only ONE window on the raw-scan path, so ablating
# fusion there is a no-op whose delta would be pure machine noise — the
# fusion axis measures its own leave-one-out PAIR on a multi-window
# workload instead (same SQL for baseline and ablated run).
AXIS_SQL = {}


def _axis_sql(name):
    if name == "window_fusion" and name not in AXIS_SQL:
        from benchmarks.bench_multiwindow import make_sql
        AXIS_SQL[name] = make_sql(4)
    return AXIS_SQL.get(name)

# row-at-a-time is pathologically slow; use a smaller replay for it
BUDGET = {"parallel_vectorized": (64, 3)}


def run(rep: Reporter) -> dict:
    base_flags = OptFlags()
    eng, data = build_engine(base_flags)
    full = replay(eng, data)
    eng.close()
    rep.add("fig2/all_on", 1e6 / full["qps"], qps=round(full["qps"], 1))

    qps_without = {}
    base_qps = {}               # per-axis all-on reference
    for name, overrides in AXES.items():
        flags = dataclasses.replace(base_flags, **overrides)
        sql = _axis_sql(name)
        batch, nb = BUDGET.get(name, (256, 10))
        if sql is not None:
            # paired baseline on the axis's own workload
            eng, data_ax = build_engine(base_flags, sql=sql)
            base_qps[name] = replay(eng, data_ax, batch=batch,
                                    n_batches=nb)["qps"]
            eng.close()
            eng, data_ax = build_engine(flags, sql=sql)
        else:
            base_qps[name] = full["qps"]
            eng, data_ax = build_engine(flags)
        r = replay(eng, data_ax, batch=batch, n_batches=nb)
        qps_without[name] = r["qps"]
        eng.close()
        rep.add(f"fig2/without_{name}", 1e6 / r["qps"],
                qps=round(r["qps"], 1))

    # leave-one-out attribution, two normalisations:
    # linear share (paper's presentation) and log share (multiplicative
    # speedups made additive — fairer when one axis dominates).
    import math
    deltas = {n: max(base_qps[n] / q - 1.0, 0.0)
              for n, q in qps_without.items()}
    total = sum(deltas.values()) or 1.0
    contrib = {n: 100.0 * d / total for n, d in deltas.items()}
    logs = {n: math.log(max(base_qps[n] / q, 1.0))
            for n, q in qps_without.items()}
    log_total = sum(logs.values()) or 1.0
    log_contrib = {n: 100.0 * v / log_total for n, v in logs.items()}
    for n in sorted(contrib, key=lambda k: -contrib[k]):
        rep.add(f"fig2/contribution_{n}", 0.0,
                linear_pct=round(contrib[n], 1),
                log_pct=round(log_contrib[n], 1),
                speedup=round(base_qps[n] / qps_without[n], 2))
    rep.add("fig2/paper_bands", 0.0,
            query_plan="30-35%", caching_mat="15-25%",
            parallel="20-25%", resource="~10%",
            note="TPU substrate shifts weight to vectorization; "
                 "see EXPERIMENTS.md Paper-validation")
    return {"full": full, "without": qps_without, "baselines": base_qps,
            "contribution": contrib, "log_contribution": log_contrib}
