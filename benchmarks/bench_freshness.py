"""Freshness-tier benchmark (DESIGN.md §14): what the data-plane
observability layer measures, and what it costs.

Three panels:

1. **Ingest-to-visible vs ingest rate** — a streamed table is fed at a
   controlled event rate with a background flusher; the freshness
   tracker's ``ingest_visible_*`` sketches give the p50/p99 staging
   delay at each rate. The expected shape: i2v is dominated by the
   flush interval at low rates and grows with staging pressure.

2. **Drift detector TP/FP** — serve a baseline workload, pin it as the
   drift reference, then (a) replay a fresh sample of the SAME
   distribution (any alarm is a false positive) and (b) shift the
   upstream data (amount +8 sigma) and replay (no alarm is a false
   negative). Reports max PSI per phase.

3. **Sketch overhead** — the acceptance gate (ISSUE 10): per-request
   freshness age + drift observation + flight-recorder breadcrumbs must
   cost <= 2% of serving p50. Measured like bench_obs_overhead: the
   on/off phases are INTERLEAVED over rounds on one warmed engine (off
   = the three hooks stubbed to no-ops) and the reported overhead is
   the MEDIAN of per-round p50 ratios, so host drift brackets out. The
   hard tripwire only fires beyond 1.5x (a structural regression, e.g.
   a sketch landing on the per-row python path).

Emits ``experiments/BENCH_freshness.json`` (quick mode writes to an
ignored ``_quick`` path so CI smoke runs never clobber the committed
trajectory).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import QUICK, Reporter, build_engine, replay

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema
from repro.obs.freshness import FreshnessTracker

N_ROUNDS = 2 if QUICK else 8
RATES = (2_000, 10_000) if QUICK else (1_000, 5_000, 20_000, 50_000)
N_STREAM_EVENTS = 1_500 if QUICK else 8_000
STREAM_FLUSH_S = 0.02
DRIFT_BATCH = 64 if QUICK else 128
DRIFT_BATCHES = 8 if QUICK else 24

OUT_PATH = os.path.join(
    "experiments",
    "bench_freshness_quick.json" if QUICK else "BENCH_freshness.json")


# ------------------------------------------------- panel 1: i2v vs rate
def _i2v_at_rate(rate: float) -> Dict[str, float]:
    """Stream N_STREAM_EVENTS at ``rate`` events/s into a fresh table
    with a background flusher; return the tracker's i2v percentiles."""
    eng = Engine(OptFlags())
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount",))
    eng.create_table(schema, max_keys=64, capacity=2048, bucket_size=256)
    pipe = eng.attach_stream("events", lateness=0.0,
                             flush_interval_s=STREAM_FLUSH_S)
    rng = np.random.default_rng(7)
    push = 64                               # events per push_batch call
    interval = push / rate
    # warm every power-of-2 ingest shape bucket outside the measurement
    # — flush sizes vary with staging pressure and each new bucket's
    # compile (~1s) would otherwise dominate whole cohorts
    ts = 0.0
    for b in (8, 16, 32, 64, 128, 256, 512, 1024):
        pipe.push_batch(rng.integers(0, 64, b),
                        ts + np.arange(b, dtype=np.float64),
                        rng.normal(size=(b, 1)))
        ts += b
        pipe.flush()
        pipe.wait_idle()
    pipe.freshness = eng.freshness = FreshnessTracker()
    next_due = time.perf_counter()
    for i in range(0, N_STREAM_EVENTS, push):
        n = min(push, N_STREAM_EVENTS - i)
        keys = rng.integers(0, 64, n)
        tss = ts + np.arange(n, dtype=np.float64)
        ts += n
        pipe.push_batch(keys, tss, rng.normal(size=(n, 1)))
        next_due += interval
        pause = next_due - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
    pipe.flush()
    exp = eng.freshness_export()
    out = {
        "rate_eps": rate,
        "i2v_p50_ms": exp["events/ingest_visible_p50_s"] * 1e3,
        "i2v_p99_ms": exp["events/ingest_visible_p99_s"] * 1e3,
        "flushes": exp["events/flushes"],
        "ingested": exp["events/ingested"],
    }
    eng.close()
    return out


# --------------------------------------------- panel 2: drift TP / FP
DRIFT_SQL = """SELECT SUM(amount) OVER w AS s, AVG(amount) OVER w AS a
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""


def _drift_phases() -> Dict[str, object]:
    eng = Engine(OptFlags())
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount",))
    eng.create_table(schema, max_keys=64, capacity=1024, bucket_size=128)
    rng = np.random.default_rng(3)
    n = 2_000
    keys = rng.integers(0, 64, n)
    ts = np.sort(rng.uniform(0, 1000.0, n))
    eng.insert("events", keys.tolist(), ts.tolist(),
               rng.normal(size=(n, 1)))
    eng.deploy("q", DRIFT_SQL)

    def serve_rounds(seed, lo, hi):
        r = np.random.default_rng(seed)
        for _ in range(DRIFT_BATCHES):
            ks = r.integers(0, 64, DRIFT_BATCH)
            rts = r.uniform(lo, hi, DRIFT_BATCH)
            eng.request("q", ks.tolist(), rts.tolist())

    serve_rounds(11, 900.0, 1000.0)         # baseline distribution
    pinned = eng.pin_drift_reference()
    serve_rounds(12, 900.0, 1000.0)         # same dist, fresh draws
    fp_report = eng.drift_report()
    fp_psi = max((v["psi"] for v in fp_report.values()
                  if math.isfinite(v["psi"])), default=0.0)
    false_positive = any(v["drifted"] for v in fp_report.values())

    # upstream shift: the amount column jumps +8 sigma for new events
    ks2 = rng.integers(0, 64, n)
    ts2 = np.sort(rng.uniform(1000.0, 2000.0, n))
    eng.insert("events", ks2.tolist(), ts2.tolist(),
               rng.normal(8.0, 1.0, size=(n, 1)))
    serve_rounds(13, 1900.0, 2000.0)
    tp_report = eng.drift_report()
    tp_psi = max((v["psi"] for v in tp_report.values()
                  if math.isfinite(v["psi"])), default=0.0)
    true_positive = any(v["drifted"] for v in tp_report.values())
    eng.close()
    return {"pinned_columns": pinned,
            "fp_max_psi": fp_psi, "false_positive": false_positive,
            "tp_max_psi": tp_psi, "true_positive": true_positive}


# ------------------------------------------- panel 3: sketch overhead
def _overhead_rounds(eng, data) -> List[Dict[str, Dict[str, float]]]:
    """Interleave freshness-on / freshness-off replays; 'off' stubs the
    three per-batch hooks (age sketch, drift observe, flight record) so
    the bracket isolates exactly the observability cost. Phase order
    ALTERNATES each round (ABBA) — host drift within a round would
    otherwise bias every ratio the same way — and the deferred sketch
    buffers are drained between phases (the control plane's tick does
    this continuously in production), so a fold never lands inside a
    measured replay."""
    noop = lambda *a, **k: None
    orig = (eng.freshness.observe_age, eng.drift.observe,
            eng.flight.record)

    def set_hooks(on: bool):
        (eng.freshness.observe_age, eng.drift.observe,
         eng.flight.record) = orig if on else (noop, noop, noop)

    def phase(on: bool):
        set_hooks(on)
        r = replay(eng, data, warm=False)
        set_hooks(True)
        eng.drift.report()                  # fold pending outside timing
        eng.freshness.snapshot()
        return r

    rounds = []
    for i in range(N_ROUNDS):
        first_off = i % 2 == 0
        a = phase(not first_off)
        b = phase(first_off)
        rounds.append({"off": a if first_off else b,
                       "on": b if first_off else a})
    return rounds


def run(rep: Reporter) -> dict:
    # panel 1
    by_rate = [_i2v_at_rate(r) for r in RATES]
    for row in by_rate:
        rep.add(f"freshness/i2v@{row['rate_eps']}eps",
                row["i2v_p50_ms"] * 1e3,
                p50_ms=round(row["i2v_p50_ms"], 3),
                p99_ms=round(row["i2v_p99_ms"], 3))

    # panel 2
    drift = _drift_phases()
    rep.add("freshness/drift", drift["tp_max_psi"] * 1e3,
            fp_max_psi=round(drift["fp_max_psi"], 4),
            tp_max_psi=round(drift["tp_max_psi"], 4),
            tp=drift["true_positive"], fp=drift["false_positive"])

    # panel 3
    eng, data = build_engine()
    eng.tracer.set_sample_rate(0.0)         # isolate the freshness cost
    replay(eng, data)                       # compiles outside rounds
    rounds = _overhead_rounds(eng, data)
    eng.close()
    ratios = [r["on"]["p50_batch_ms"] / r["off"]["p50_batch_ms"]
              for r in rounds]
    # the acceptance estimator is min-over-rounds p50 on each side:
    # scheduler noise on a shared host is one-sided (contention only
    # ever ADDS latency), so the min is the stable estimate of the true
    # cost where the per-round ratio median still swings +-10%
    ratio = (min(r["on"]["p50_batch_ms"] for r in rounds)
             / min(r["off"]["p50_batch_ms"] for r in rounds))

    def med(key, field="p50_batch_ms"):
        return float(np.median([r[key][field] for r in rounds]))

    rep.add("freshness/overhead", ratio * 100.0,
            p50_ratio=round(ratio, 4),
            on_p50_ms=round(med("on"), 3),
            off_p50_ms=round(med("off"), 3))

    summary = {
        "quick": QUICK,
        "n_rounds": N_ROUNDS,
        "i2v_by_rate": by_rate,
        "drift": drift,
        "on": {"qps": med("on", "qps"), "p50_ms": med("on"),
               "p99_ms": med("on", "p99_batch_ms")},
        "off": {"qps": med("off", "qps"), "p50_ms": med("off"),
                "p99_ms": med("off", "p99_batch_ms")},
        "p50_overhead": ratio,
        "within_2pct": ratio <= 1.02,
        "per_round_ratio": ratios,
    }
    if ratio > 1.5:
        raise RuntimeError(
            f"freshness observation added {ratio:.2f}x to serving p50 — "
            f"a sketch has landed on the per-row python path")
    if not drift["true_positive"]:
        raise RuntimeError(
            f"drift detector missed an 8-sigma upstream shift "
            f"(max psi {drift['tp_max_psi']:.3f})")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    return summary


if __name__ == "__main__":
    r = Reporter()
    out = run(r)
    print(r.emit())
    print(json.dumps(out, indent=1))
