"""Paper Eq. 4: T = P / L — throughput vs parallelism.

Two parallelism forms (DESIGN.md §2 hardware adaptation):

* vectorised batch width B — the TPU-native analogue of worker threads
  (vector lanes ≈ threads). QPS should rise strongly with B.
* worker-pool threads P — the paper's literal mechanism, reproduced for
  ablation fidelity. NOTE: this container has ONE physical core, so thread
  scaling is expected ~flat here; on a multi-core host it tracks P (the
  paper's 12-thread setup). We report it honestly.
"""
from __future__ import annotations

import dataclasses

from repro.core.optimizer import OptFlags

from benchmarks.common import Reporter, build_engine, replay

BATCHES = (1, 4, 16, 64, 256, 1024)
WORKERS = (1, 2, 4)


def run(rep: Reporter) -> dict:
    out = {"batch": {}, "workers": {}}
    # --- vectorised width sweep -------------------------------------------
    eng, data = build_engine()
    for B in BATCHES:
        r = replay(eng, data, batch=B, n_batches=max(3, 512 // B))
        out["batch"][B] = r["qps"]
        rep.add(f"eq4/batch_B={B}", 1e6 / r["qps"],
                qps=round(r["qps"], 1),
                p50_batch_ms=round(r["p50_batch_ms"], 3))
    eng.close()
    scale = out["batch"][256] / out["batch"][1]
    rep.add("eq4/vector_scaling_256_vs_1", 0.0, speedup=round(scale, 1))

    # --- worker-pool sweep (paper-literal; 1-core container) ---------------
    for P in WORKERS:
        flags = OptFlags(parallel_workers=P)
        eng, data = build_engine(flags)
        r = replay(eng, data, batch=256, n_batches=8)
        out["workers"][P] = r["qps"]
        rep.add(f"eq4/workers_P={P}", 1e6 / r["qps"],
                qps=round(r["qps"], 1))
        eng.close()
    return out
