"""Shared benchmark harness: engine construction + workload replay.

Every bench emits rows ``(name, us_per_call, derived)`` where ``derived``
is a bench-specific dict (qps, p50_ms, ...). ``benchmarks.run`` prints the
canonical CSV and writes experiments/bench_results.json.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  request_stream)
from repro.featurestore.table import TableSchema

# REPRO_BENCH_QUICK=1 (or `benchmarks.run --quick`) shrinks every bench
# to a CI-smoke size: same code paths, ~10x less work. Numbers from a
# quick run are regression tripwires, not paper-validation figures.
QUICK = os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")

# The paper's workload shape: 100-500 records/batch, 6-12 parallel
# requests/batch; we default to the midpoint.
N_EVENTS = 2_000 if QUICK else 20_000
N_KEYS = 64 if QUICK else 256
REQ_BATCH = 64 if QUICK else 256
N_REQ_BATCHES = 4 if QUICK else 30

FEATURE_SQL = """
SELECT
  SUM(amount)  OVER w1 AS amt_sum_10,
  AVG(amount)  OVER w1 AS amt_avg_10,
  MAX(amount)  OVER w1 AS amt_max_10,
  COUNT(amount) OVER w1 AS txn_cnt_10,
  STD(amount)  OVER w1 AS amt_std_10,
  AVG(lat)     OVER w2 AS lat_avg_100,
  AVG(lon)     OVER w2 AS lon_avg_100,
  MIN(amount)  OVER w2 AS amt_min_100,
  MAX(amount)  OVER w2 AS amt_max_100,
  LAST(amount) OVER w1 AS amt_last
FROM events
WINDOW w1 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
       w2 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 100 PRECEDING AND CURRENT ROW)
"""


def build_engine(flags: OptFlags = OptFlags(), *, n_events: int = N_EVENTS,
                 n_keys: int = N_KEYS, sql: str = FEATURE_SQL,
                 capacity: int = 1024, bucket_size: int = 64,
                 name: str = "bench") -> Tuple[Engine, tuple]:
    eng = Engine(flags)
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount", "lat", "lon", "cat",
                                     "drift", "drift2"))
    eng.create_table(schema, max_keys=n_keys, capacity=capacity,
                     bucket_size=bucket_size)
    data = generate_events(EventStreamConfig(n_events=n_events,
                                             n_keys=n_keys, n_features=6))
    keys, ts, rows = data
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy(name, sql)
    return eng, data


def replay(eng: Engine, data, *, deployment: str = "bench",
           batch: int = REQ_BATCH, n_batches: int = N_REQ_BATCHES,
           serve: Optional[Callable] = None, warm: bool = True
           ) -> Dict[str, float]:
    """Replay the online workload; returns qps + latency percentiles."""
    keys, ts, _ = data
    serve = serve or (lambda ks, rts: eng.request(
        deployment, ks.tolist(), rts.tolist()))
    if warm:
        for ks, rts in request_stream(keys, ts, batch=batch, n_batches=1,
                                      seed=99):
            serve(ks, rts)
    lats: List[float] = []
    n = 0
    t_start = time.perf_counter()
    for ks, rts in request_stream(keys, ts, batch=batch,
                                  n_batches=n_batches):
        t0 = time.perf_counter()
        serve(ks, rts)
        lats.append(time.perf_counter() - t0)
        n += len(ks)
    wall = time.perf_counter() - t_start
    lat = np.asarray(lats)
    return {
        "qps": n / wall,
        "p50_batch_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_batch_ms": float(np.percentile(lat, 99) * 1e3),
        "p50_req_ms": float(np.percentile(lat, 50) * 1e3 / batch),
        "n_requests": n,
        "wall_s": wall,
    }


class Reporter:
    def __init__(self):
        self.rows: List[Tuple[str, float, Dict]] = []

    def add(self, name: str, us_per_call: float, **derived):
        self.rows.append((name, us_per_call, derived))

    def emit(self) -> str:
        out = ["name,us_per_call,derived"]
        for name, us, derived in self.rows:
            out.append(f"{name},{us:.2f},"
                       + json.dumps(derived, sort_keys=True).replace(",", ";"))
        return "\n".join(out)
