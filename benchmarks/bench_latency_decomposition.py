"""Paper Eq. 3: L = L_parse + L_plan + L_exec.

Measures the decomposition directly from the engine's stats counters:
cold deploy (parse+plan), first request (JIT, charged to plan as the
paper charges compilation), then steady-state exec. Validates that the
plan cache drives L_plan -> 0 in steady state.
"""
from __future__ import annotations

from benchmarks.common import Reporter, build_engine, replay


def run(rep: Reporter) -> dict:
    eng, data = build_engine()
    keys, ts, _ = data
    d0 = eng.latency_decomposition()          # after deploy: parse+plan
    rep.add("eq3/deploy", 0.0,
            parse_ms=round(d0["parse_s"] * 1e3, 3),
            plan_ms=round(d0["plan_s"] * 1e3, 3))

    B = 256
    eng.request("bench", keys[:B].tolist(), [float(ts.max()) + 1] * B)
    d1 = eng.latency_decomposition()          # + first-request JIT
    rep.add("eq3/first_request", 0.0,
            jit_plan_ms=round((d1["plan_s"] - d0["plan_s"]) * 1e3, 2),
            exec_ms=round(d1["exec_s"] * 1e3, 3))

    r = replay(eng, data, n_batches=20, warm=False)
    d2 = eng.latency_decomposition()
    steady_plan_ms = (d2["plan_s"] - d1["plan_s"]) * 1e3
    steady_exec_ms = (d2["exec_s"] - d1["exec_s"]) * 1e3
    total = (d2["parse_s"] + d2["plan_s"] + d2["exec_s"])
    rep.add("eq3/steady_state", 1e6 / r["qps"],
            plan_ms_total=round(steady_plan_ms, 4),
            exec_ms_total=round(steady_exec_ms, 2),
            cache_hit_rate=round(d2["cache_hit_rate"], 3),
            kernel_launches=d2["kernel_launches"],
            plan_share=round(steady_plan_ms
                             / max(steady_exec_ms + steady_plan_ms, 1e-9), 4))
    eng.close()
    return {"deploy": d0, "steady": d2, "qps": r["qps"]}
