"""Sharded serving runtime: aggregate QPS / p50 / p99 vs shard count.

Measures the scale-out story of DESIGN.md §9: the SAME deployment served
by the sharded runtime at 1, 2 and 4 shards under saturating concurrent
load.

**Load model: open loop.** Feeders keep every shard worker's queue
primed at constant depth with PRE-scattered, dispatch-sized sub-batches
and count completed rows — the standard way to measure a serving data
plane's capacity (a closed-loop client convoy on a 2-core box measures
the client's own np/GIL work as much as the server; we saw it mask a
1.4x data-plane speedup entirely). The full client path — admission
control, scatter, gather, shedding — is exercised by the parity check
here and end-to-end in tests/test_shard.py; its per-batch overhead is
client-side and shard-count-independent.

**Process model.** Two modes (``REPRO_SHARD_BENCH_MODE`` / ``run(rep,
mode=...)``):

* ``inprocess`` (default): shards are pinned one-per-XLA-device; on CPU
  hosts the runtime's serving process is launched with
  ``--xla_force_host_platform_device_count=N`` so each shard owns a
  device execution stream (the CPU stand-in for one tablet per
  accelerator). jax reads that flag at init, so the measurement runs in
  a SUBPROCESS spawned with the right env — ``run(rep)`` from
  ``benchmarks.run`` does this automatically; the child re-enters this
  module with ``REPRO_SHARD_BENCH_CHILD=1``.
* ``process`` (DESIGN.md §11): each shard is its own subprocess worker
  with a private jax runtime — true multi-core scale-out with no shared
  GIL or XLA threadpool. Acceptance (ISSUE 7): 4-shard >= 2.0x 1-shard
  median QPS **on a >= 4-core host** (the summary records ``cores``; on
  fewer cores the workers time-slice and the ratio is noise).

**Drift discipline** (the 2-core CI host swings ±2x run-to-run): every
round measures all shard counts back-to-back (interleaved A/B), the
1-shard baseline is re-measured adjacent to every treated phase, and the
acceptance ratio is the MEDIAN over per-round ratios — point comparisons
on this box are meaningless (we measured 2x swings between phases
minutes apart).

Acceptance (ISSUE 5): 4-shard aggregate QPS >= 1.3x the 1-shard
baseline, plus sharded-vs-unsharded bit-identical outputs (asserted here
on a spot batch; exhaustively in tests/test_shard.py). Emits
``experiments/BENCH_shard.json`` (quick mode writes an ignored
``_quick`` path so CI smoke runs never clobber the committed numbers).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List

QUICK = os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")
# "inprocess" (default: shards share this process, one per XLA device) or
# "process" (DESIGN.md §11: one subprocess per shard — true multi-core
# scaling without the GIL/XLA-threadpool sharing of the in-process mode)
MODE = os.environ.get("REPRO_SHARD_BENCH_MODE", "inprocess")

SHARD_COUNTS = (1, 2, 4)
N_KEYS = 512 if QUICK else 4096
N_EVENTS = 10_000 if QUICK else 80_000
CAPACITY = 256
DISPATCH_ROWS = 256
ROUNDS = 2 if QUICK else 9
ROUND_SECONDS = 1.5 if QUICK else 3.0
WARM_SECONDS = 1.0 if QUICK else 2.0


def _out_path(mode: str, quick: bool) -> str:
    tag = "shard_proc" if mode == "process" else "shard"
    return os.path.join(
        "experiments",
        f"bench_{tag}_quick.json" if quick else f"BENCH_{tag}.json")


OUT_PATH = _out_path(MODE, QUICK)

SQL = """
SELECT
  SUM(c0) OVER w1 AS f0,  AVG(c1) OVER w1 AS f1,
  MAX(c2) OVER w1 AS f2,  STD(c3) OVER w1 AS f3,
  SUM(c4) OVER w2 AS f4,  AVG(c5) OVER w2 AS f5,
  MIN(c6) OVER w2 AS f6,  LAST(c7) OVER w2 AS f7,
  COUNT(c0) OVER w1 AS f8
FROM events
WINDOW w1 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 16 PRECEDING AND CURRENT ROW),
       w2 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 64 PRECEDING AND CURRENT ROW)
"""


# ---------------------------------------------------------------------------
# child process: the actual measurement (needs the device-count XLA flag
# in place BEFORE jax initializes)
# ---------------------------------------------------------------------------

def _build(n_shards: int, data):
    import numpy as np
    from repro.core.optimizer import OptFlags
    from repro.featurestore.table import TableSchema
    from repro.shard import AdmissionConfig, ShardConfig, ShardedEngine

    keys, ts, rows = data
    se = ShardedEngine(
        ShardConfig(n_shards=n_shards, dispatch_rows=DISPATCH_ROWS,
                    admission=AdmissionConfig(max_inflight=64,
                                              max_queue_depth=512),
                    backend=("process" if MODE == "process" else None)),
        flags=OptFlags(),
        warm_buckets=(8, 16, 32, 64, 128, 256))
    se.create_table(
        TableSchema("events", key_col="user", ts_col="ts",
                    value_cols=tuple(f"c{i}" for i in range(10))),
        max_keys=N_KEYS, capacity=CAPACITY, bucket_size=64)
    se.insert("events", keys.tolist(), ts.tolist(), rows)
    se.deploy("bench", SQL)
    return se


def _make_data():
    import numpy as np
    rng = np.random.default_rng(0)
    keys = rng.integers(0, N_KEYS, N_EVENTS)
    ts = np.sort(rng.uniform(0, 2000.0, N_EVENTS)).astype(np.float32)
    rows = rng.normal(size=(N_EVENTS, 10)).astype(np.float32)
    return keys, ts, rows


def _make_streams(se, ts_max: float, seed: int = 1):
    """Pre-scattered request streams: per shard, a rotation of fixed
    ``DISPATCH_ROWS``-sized sub-batches of that shard's own keys.

    Building the scatter OFFLINE makes the measurement open-loop: the
    load generator's own np work cannot convoy with the runtime under
    test (closed-loop clients on this 2-core box measure the client as
    much as the server). Sub-batch sizes are fixed at the dispatch chunk
    so every shard count serves identically-shaped dispatches."""
    import numpy as np
    S = se.n_shards
    rng = np.random.default_rng(seed)
    # route with the engine's OWN partitioner (consistent-hash ring by
    # default) — a modulo pre-scatter would feed shards keys they don't
    # own and measure unknown-key lookups instead of feature serves
    sid = se.owners_of(np.arange(N_KEYS))
    pools = [np.flatnonzero(sid == s) for s in range(S)]
    streams = []
    for s in range(S):
        subs = []
        for i in range(16):
            rk = rng.choice(pools[s], DISPATCH_ROWS)
            rt = np.full(DISPATCH_ROWS, ts_max + 1.0 + i, np.float32)
            subs.append((rk, rt))
        streams.append(subs)
    return streams


def _run_load(se, streams, seconds: float) -> Dict[str, float]:
    """Open-loop saturating load on the serving data plane: one feeder
    per shard keeps its worker queue primed at constant depth with
    pre-scattered sub-batches (YCSB-style), counting COMPLETED rows.
    Aggregate QPS = completed rows / wall; per-sub-batch latency gives
    p50/p99 (queueing included)."""
    import numpy as np
    from collections import deque
    from repro.shard.router import SubBatch
    dep = se.handle("bench")
    DEPTH = 3
    stop = threading.Event()
    counts = [0] * se.n_shards
    lats: List[float] = []
    errs: List[BaseException] = []

    def feeder(s: int) -> None:
        subs = streams[s]
        handle = dep.handles[s]
        pending: deque = deque()
        i = 0
        try:
            while not stop.is_set():
                while len(pending) < DEPTH:
                    rk, rt = subs[i % len(subs)]
                    i += 1
                    item = SubBatch(handle, rk, rt, None)
                    pending.append((time.perf_counter(),
                                    se.router.submit(s, item)))
                t0, head = pending.popleft()
                head.done.wait(120.0)
                if head.error is not None:
                    raise head.error
                lats.append(time.perf_counter() - t0)
                counts[s] += len(head)
        except BaseException as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=feeder, args=(s,))
               for s in range(se.n_shards)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(seconds)
    stop.set()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    wall = time.perf_counter() - t0
    lat = np.asarray(lats) if lats else np.asarray([float("nan")])
    return {"qps": sum(counts) / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def _parity_spot_check(engines, data) -> bool:
    """Sharded outputs must be bit-identical across shard counts."""
    import numpy as np
    keys, ts, rows = data
    rng = np.random.default_rng(42)
    rk = rng.integers(0, N_KEYS, 64)
    rt = np.full(64, float(ts.max()) + 10_000.0, np.float32)
    frames = {n: se.request("bench", rk, rt) for n, se in engines.items()}
    base = frames[1]
    for n, f in frames.items():
        for col in base:
            if not np.array_equal(np.asarray(base[col]),
                                  np.asarray(f[col])):
                return False
    return True


def child_main() -> int:
    import numpy as np
    import jax
    data = _make_data()
    ts_max = float(data[1].max())
    engines = {}
    t_build0 = time.time()
    for n in SHARD_COUNTS:
        engines[n] = _build(n, data)
    build_s = time.time() - t_build0
    parity_ok = _parity_spot_check(engines, data)

    streams = {n: _make_streams(engines[n], ts_max)
               for n in SHARD_COUNTS}
    # warm every config's serve path (compiles happen here, not in rounds)
    for n in SHARD_COUNTS:
        _run_load(engines[n], streams[n], WARM_SECONDS)

    rounds: List[Dict[int, Dict[str, float]]] = []
    for r in range(ROUNDS):
        per: Dict[int, Dict[str, float]] = {}
        for n in SHARD_COUNTS:       # interleaved: every round has all
            per[n] = _run_load(engines[n], streams[n], ROUND_SECONDS)
        rounds.append(per)
        print(f"# round {r}: " + "  ".join(
            f"{n}sh={per[n]['qps']:,.0f}" for n in SHARD_COUNTS),
            file=sys.stderr)

    ratios4 = [rd[4]["qps"] / rd[1]["qps"] for rd in rounds]
    ratios2 = [rd[2]["qps"] / rd[1]["qps"] for rd in rounds]
    summary = {
        "quick": QUICK,
        "mode": MODE,
        "devices": len(jax.devices()),
        "cores": os.cpu_count() or 1,
        "shard_counts": list(SHARD_COUNTS),
        "load": "open-loop primed queues, depth 3 per shard",
        "dispatch_rows": DISPATCH_ROWS,
        "rounds": ROUNDS,
        "build_s": round(build_s, 1),
        "by_shards": {
            str(n): {
                "qps": float(np.median([rd[n]["qps"] for rd in rounds])),
                "p50_ms": float(np.median([rd[n]["p50_ms"]
                                           for rd in rounds])),
                "p99_ms": float(np.median([rd[n]["p99_ms"]
                                           for rd in rounds])),
            } for n in SHARD_COUNTS},
        "per_round": [{str(n): rd[n] for n in SHARD_COUNTS}
                      for rd in rounds],
        "four_shard_speedup_median": float(np.median(ratios4)),
        "two_shard_speedup_median": float(np.median(ratios2)),
        "parity_spot_check": parity_ok,
        # acceptance views (ISSUE 5: in-process >= 1.3x; ISSUE 7:
        # process backend >= 2.0x — the 2x claim presumes >= 4 physical
        # cores, so `cores` is recorded alongside it)
        "meets_1_3x": bool(np.median(ratios4) >= 1.3) and parity_ok,
        "meets_2x": bool(np.median(ratios4) >= 2.0) and parity_ok,
        "router": engines[4].router.stats(),
        "admission": engines[4].resources.metrics(),
    }
    for se in engines.values():
        se.close()
    if not parity_ok:
        # parity is structural — a mismatch is a routing bug, not noise
        raise RuntimeError("sharded outputs diverged across shard counts")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("per_round", "by_shards")},
                     indent=1), file=sys.stderr)
    return 0


# ---------------------------------------------------------------------------
# parent: spawn the child with the device-count flag, read its JSON
# ---------------------------------------------------------------------------

def _spawn_child(mode: str = MODE) -> dict:
    env = dict(os.environ)
    if mode == "process":
        # shard workers are their own subprocesses, each pinning ONE XLA
        # device in its own env (worker_env) — the bench child itself
        # stays single-device and only scatters/collects
        env["REPRO_SHARD_BENCH_MODE"] = "process"
    else:
        env.pop("REPRO_SHARD_BENCH_MODE", None)
        flags = env.get("XLA_FLAGS", "")
        # one device per shard, CAPPED at the physical core count:
        # execution streams beyond real cores just thrash (4 streams on
        # 2 cores measured ~35% slower than 2); shards fold onto devices
        # via s % D, exactly like tablets sharing a server
        n_dev = min(max(SHARD_COUNTS), os.cpu_count() or 2)
        want = f"--xla_force_host_platform_device_count={n_dev}"
        if "xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = (flags + " " + want).strip()
    env["REPRO_SHARD_BENCH_CHILD"] = "1"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_shard_scaling"],
        env=env, timeout=3000,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_shard_scaling child exited {proc.returncode}")
    with open(_out_path(mode, QUICK)) as f:
        return json.load(f)


def run(rep, mode: str = "inprocess") -> dict:
    """benchmarks.run entry point (parent side)."""
    summary = _spawn_child(mode)
    tag = "shard_proc" if mode == "process" else "shard"
    for n in summary["shard_counts"]:
        row = summary["by_shards"][str(n)]
        rep.add(f"{tag}/shards={n}", 1e6 / row["qps"],
                qps=round(row["qps"], 1), p50_ms=round(row["p50_ms"], 3),
                p99_ms=round(row["p99_ms"], 3))
    rep.add(f"{tag}/4v1_speedup", 0.0,
            median=round(summary["four_shard_speedup_median"], 3),
            meets_1_3x=summary["meets_1_3x"],
            meets_2x=summary["meets_2x"],
            parity=summary["parity_spot_check"])
    return summary


if __name__ == "__main__":
    if os.environ.get("REPRO_SHARD_BENCH_CHILD"):
        sys.exit(child_main())
    from benchmarks.common import Reporter
    r = Reporter()
    out = run(r, mode=MODE)
    print(r.emit())
    print(json.dumps({k: v for k, v in out.items() if k != "per_round"},
                     indent=1))
