"""Benchmark driver: one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,...]

Prints the canonical ``name,us_per_call,derived`` CSV and writes the full
results to experiments/bench_results.json. §Paper-validation in
EXPERIMENTS.md reads from that JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,table1,preagg,eq3,eq4,"
                         "stream,hotswap,multiwindow,lastjoin,shard,"
                         "shard_proc,adaptive,recovery,obs,freshness")
    ap.add_argument("--quick", action="store_true",
                    help="reduced-size smoke mode (CI): same code paths, "
                         "~10x less work; numbers are tripwires only")
    ap.add_argument("--out", default="experiments/bench_results.json")
    args = ap.parse_args(argv)
    if args.quick:
        # must land before benchmarks.common is imported — its workload
        # constants are resolved at import time
        os.environ["REPRO_BENCH_QUICK"] = "1"
    only = set(args.only.split(",")) if args.only else None

    from benchmarks.common import Reporter
    rep = Reporter()
    results = {}
    t0 = time.time()

    def want(name):
        return only is None or name in only

    fig1_results = None
    if want("fig1") or want("table1"):
        from benchmarks import bench_fig1_qps_latency as b1
        fig1_results = b1.run(rep)
        results["fig1"] = {k: v for k, v in fig1_results.items()}
    if want("fig2"):
        from benchmarks import bench_fig2_ablation as b2
        results["fig2"] = b2.run(rep)
    if want("table1") and fig1_results:
        from benchmarks import bench_table1_systems as b3
        results["table1"] = b3.run(rep, fig1_results)
    if want("preagg"):
        from benchmarks import bench_preagg_scaling as b4
        results["preagg"] = b4.run(rep)
    if want("eq3"):
        from benchmarks import bench_latency_decomposition as b5
        results["eq3"] = b5.run(rep)
    if want("eq4"):
        from benchmarks import bench_parallel_scaling as b6
        results["eq4"] = b6.run(rep)
    if want("stream"):
        from benchmarks import bench_stream_interference as b7
        results["stream"] = b7.run(rep)
    if want("hotswap"):
        from benchmarks import bench_hotswap as b8
        results["hotswap"] = b8.run(rep)
    if want("multiwindow"):
        from benchmarks import bench_multiwindow as b9
        results["multiwindow"] = b9.run(rep)
    if want("lastjoin"):
        from benchmarks import bench_lastjoin as b10
        results["lastjoin"] = b10.run(rep)
    if want("shard"):
        # runs in a subprocess (needs --xla_force_host_platform_device_count
        # in XLA_FLAGS before jax init; this parent already initialized jax)
        from benchmarks import bench_shard_scaling as b11
        results["shard"] = {k: v for k, v in b11.run(rep).items()
                           if k != "per_round"}
    if want("shard_proc"):
        # same bench, process-backed shard runtime (one subprocess per
        # shard, DESIGN.md §11)
        from benchmarks import bench_shard_scaling as b11p
        results["shard_proc"] = {
            k: v for k, v in b11p.run(rep, mode="process").items()
            if k != "per_round"}
    if want("adaptive"):
        from benchmarks import bench_adaptive as b12
        results["adaptive"] = b12.run(rep)
    if want("recovery"):
        # durability tier: kill-to-parity MTTR, WAL+standby vs cold
        # respawn (process-backed workers set their own jax env)
        from benchmarks import bench_recovery as b13
        results["recovery"] = {k: v for k, v in b13.run(rep).items()
                               if k != "per_round"}
    if want("obs"):
        # observability tier: tracing on/off overhead bracketed against
        # host drift, plus exporter render costs (DESIGN.md §13)
        from benchmarks import bench_obs_overhead as b14
        results["obs"] = b14.run(rep)
    if want("freshness"):
        # data-plane observability: ingest-to-visible latency vs rate,
        # drift detector TP/FP, sketch overhead bracket (DESIGN.md §14)
        from benchmarks import bench_freshness as b15
        results["freshness"] = b15.run(rep)

    print(rep.emit())
    print(f"# total bench wall time: {time.time() - t0:.1f}s",
          file=sys.stderr)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"rows": [(n, u, d) for n, u, d in rep.rows],
                   "results": results}, f, indent=1, default=str)
    summarize_benches()
    return 0


# ---------------------------------------------------------------------------
# cross-PR perf trajectory: experiments/BENCH_summary.json
# ---------------------------------------------------------------------------

def _headline(name: str, doc: dict):
    """Extract one headline {qps, p50_ms, p99_ms} row from a per-bench
    JSON. Known schemas are pulled exactly; anything else falls back to
    the first nested dict carrying qps+latency keys."""
    if name == "multiwindow" and "by_specs" in doc:
        top = doc["by_specs"][max(doc["by_specs"], key=int)]
        return {"qps": top["fused"]["qps"],
                "p50_ms": top["fused"]["p50_ms"],
                "p99_ms": top["fused"]["p99_ms"],
                "detail": f"fused, {top['n_specs']} specs"}
    if name == "lastjoin" and "by_joins" in doc:
        top = doc["by_joins"][max(doc["by_joins"], key=int)]
        return {"qps": top["qps"], "p50_ms": top["p50_ms"],
                "p99_ms": top["p99_ms"],
                "detail": f"{top['extra_launches']} joined table(s)"}
    if name == "recovery" and "mttr_speedup" in doc:
        # MTTR bench: no qps — headline is the kill-to-parity time
        return {"qps": None,
                "p50_ms": doc["durable_parity_s_median"] * 1e3,
                "p99_ms": doc["baseline_parity_s_median"] * 1e3,
                "detail": (f"durable vs baseline parity MTTR, "
                           f"{doc['mttr_speedup']:.2f}x, "
                           f"meets_2x={doc['meets_2x']}")}
    if name == "obs" and "full" in doc:
        # overhead bench: headline is the fully-traced phase, with the
        # bracketed overhead ratio as the detail
        return {"qps": doc["full"]["qps"],
                "p50_ms": doc["full"]["p50_ms"],
                "p99_ms": doc["full"]["p99_ms"],
                "detail": (f"tracing@1.0, "
                           f"{doc['p50_overhead_full']:.3f}x vs off, "
                           f"within_5pct={doc['within_5pct']}")}
    if name == "freshness" and "p50_overhead" in doc:
        # headline is the freshness-on serving phase; the bracketed
        # overhead and drift verdicts ride in the detail
        return {"qps": doc["on"]["qps"], "p50_ms": doc["on"]["p50_ms"],
                "p99_ms": doc["on"]["p99_ms"],
                "detail": (f"freshness on, "
                           f"{doc['p50_overhead']:.3f}x vs off, "
                           f"within_2pct={doc['within_2pct']}, "
                           f"drift tp={doc['drift']['true_positive']} "
                           f"fp={doc['drift']['false_positive']}")}
    if name in ("shard", "shard_proc") and "by_shards" in doc:
        top = doc["by_shards"][max(doc["by_shards"], key=int)]
        return {"qps": top["qps"], "p50_ms": top["p50_ms"],
                "p99_ms": top["p99_ms"],
                "detail": (f"{max(doc['by_shards'], key=int)} shards, "
                           f"{doc.get('four_shard_speedup_median', 0):.2f}x "
                           f"vs 1, {doc.get('mode', 'inprocess')}")}

    def find(d):
        if isinstance(d, dict):
            keys = set(d)
            if "qps" in keys and ({"p50_ms", "p99_ms"} & keys
                                  or "p50_batch_ms" in keys):
                return {"qps": d["qps"],
                        "p50_ms": d.get("p50_ms", d.get("p50_batch_ms")),
                        "p99_ms": d.get("p99_ms", d.get("p99_batch_ms"))}
            for v in d.values():
                r = find(v)
                if r is not None:
                    return r
        return None

    return find(doc)


def summarize_benches(exp_dir: str = "experiments",
                      out_name: str = "BENCH_summary.json") -> str:
    """Aggregate every per-bench ``BENCH_*.json`` into one machine-readable
    name -> headline (QPS/p50/p99) map, so the perf trajectory across PRs
    is a single file diff instead of N schemas."""
    import glob
    summary = {}
    for path in sorted(glob.glob(os.path.join(exp_dir, "BENCH_*.json"))):
        fname = os.path.basename(path)
        if fname == out_name:
            continue
        name = fname[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            summary[name] = {"error": str(e), "source": fname}
            continue
        head = _headline(name, doc)
        summary[name] = {
            **({k: round(v, 3) if isinstance(v, float) else v
                for k, v in head.items()} if head else
               {"error": "no qps/p50 headline found"}),
            "quick": bool(doc.get("quick", False)) if isinstance(doc, dict)
            else False,
            "source": fname,
        }
    out_path = os.path.join(exp_dir, out_name)
    os.makedirs(exp_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    print(f"# wrote {out_path}: {sorted(summary)}", file=sys.stderr)
    return out_path


if __name__ == "__main__":
    sys.exit(main())
