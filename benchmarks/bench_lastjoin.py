"""Relational tier: QPS / p50 / p99 vs number of LAST JOINed tables.

The paper's flagship scenarios are multi-table (a transaction request
enriched with the latest merchant/account/device rows as of the request
timestamp); this bench measures what that enrichment costs on the serving
hot path: the same two-window feature query served with 0, 1, 2, and 3
point-in-time LAST JOINs, each join adding exactly ONE kernel launch
(asserted from the plan counter).

Drift bracketing (the 2-core CI host swings ±2x run-to-run): the 0-join
baseline is measured BEFORE and AFTER the joined sweep on the same warmed
engine, and the joined p50s are compared against the MEAN of the two
brackets — machine drift cancels at the comparison point.

Acceptance tripwire (ISSUE 4): a 3-table joined request must stay within
2.5x the single-table baseline p50. Emits
``experiments/BENCH_lastjoin.json`` (quick mode writes to an ignored
``_quick`` path so CI smoke runs never clobber the committed trajectory).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import dsl
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.featurestore.table import TableSchema

from benchmarks.common import QUICK, Reporter

N_EVENTS = 2_000 if QUICK else 20_000
N_KEYS = 64 if QUICK else 256
REQ_BATCH = 64 if QUICK else 256
N_REQ_BATCHES = 4 if QUICK else 24
N_DIM_KEYS = 64
JOIN_COUNTS = (0, 1, 2, 3)

OUT_PATH = os.path.join(
    "experiments",
    "bench_lastjoin_quick.json" if QUICK else "BENCH_lastjoin.json")


def build_engine(n_joins: int):
    eng = Engine(OptFlags())
    eng.create_table(
        TableSchema("events", key_col="user", ts_col="ts",
                    value_cols=("amount", "lat", "m0", "m1", "m2")),
        max_keys=N_KEYS, capacity=1024, bucket_size=64)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, N_KEYS, N_EVENTS)
    ts = np.sort(rng.uniform(0, 2000.0, N_EVENTS)).astype(np.float32)
    rows = np.stack(
        [rng.lognormal(1.0, 1.0, N_EVENTS),
         rng.normal(0, 1, N_EVENTS)]
        + [rng.integers(0, N_DIM_KEYS, N_EVENTS).astype(np.float64)
           for _ in range(3)], -1).astype(np.float32)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)

    for d in range(n_joins):
        # the join key column shares its name across both sides (the
        # left table's m<d> column holds dim<d> keys)
        eng.create_table(
            TableSchema(f"dim{d}", key_col=f"m{d}", ts_col="dts",
                        value_cols=("a", "b")),
            max_keys=N_DIM_KEYS, capacity=128, bucket_size=16)
        # a few profile re-publishes per dim key (point-in-time history)
        for t0 in (100.0, 700.0, 1500.0):
            dk = list(range(N_DIM_KEYS))
            eng.insert(f"dim{d}", dk, [t0] * N_DIM_KEYS,
                       np.stack([np.arange(N_DIM_KEYS) + t0,
                                 np.arange(N_DIM_KEYS) * 0.5],
                                -1).astype(np.float32))

    qb = (dsl.QueryBuilder("events")
          .window("w1", partition_by="user", order_by="ts", rows=16)
          .window("w2", partition_by="user", order_by="ts", rows=64)
          .select(s1=dsl.sum_(dsl.col("amount")).over("w1"),
                  a1=dsl.avg_(dsl.col("amount")).over("w1"),
                  l1=dsl.last_(dsl.col("amount")).over("w1"),
                  s2=dsl.sum_(dsl.col("amount")).over("w2"),
                  x2=dsl.max_(dsl.col("lat")).over("w2")))
    for d in range(n_joins):
        qb.last_join(f"dim{d}", on=f"m{d}", order_by="dts")
        qb.select(**{f"ja{d}": dsl.tbl(f"dim{d}").a,
                     f"jb{d}": dsl.tbl(f"dim{d}").b})
    eng.deploy("bench", qb, warm_buckets=(REQ_BATCH,))
    return eng, (keys, ts, rows)


def run_phase(eng, data, *, seed=11) -> Dict[str, float]:
    keys, ts, rows = data
    rng = np.random.default_rng(seed)
    t_hi = float(ts.max())
    lats, n = [], 0
    t_start = time.perf_counter()
    for b in range(N_REQ_BATCHES):
        idx = rng.integers(0, len(keys), REQ_BATCH)
        rk = keys[idx].tolist()
        rt = np.full(REQ_BATCH, t_hi + 1.0 + b, np.float32).tolist()
        rr = rows[idx]                      # join probe keys ride along
        t0 = time.perf_counter()
        eng.request("bench", rk, rt, rows=rr)
        lats.append(time.perf_counter() - t0)
        n += REQ_BATCH
    wall = time.perf_counter() - t_start
    lat = np.asarray(lats)
    return {"qps": n / wall,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def run(rep: Reporter) -> dict:
    engines = {}
    for n in JOIN_COUNTS:
        engines[n] = build_engine(n)
        run_phase(*engines[n], seed=99)     # warm every bucket/path

    launches = {n: engines[n][0].handle("bench").phys.n_kernel_launches
                for n in JOIN_COUNTS}
    base_pre = run_phase(*engines[0])
    joined = {n: run_phase(*engines[n]) for n in JOIN_COUNTS if n > 0}
    base_post = run_phase(*engines[0])
    for eng, _ in engines.values():
        eng.close()

    base_p50 = 0.5 * (base_pre["p50_ms"] + base_post["p50_ms"])
    results = {0: {"qps": 0.5 * (base_pre["qps"] + base_post["qps"]),
                   "p50_ms": base_p50,
                   "p99_ms": 0.5 * (base_pre["p99_ms"]
                                    + base_post["p99_ms"]),
                   "launches": launches[0], "extra_launches": 0}}
    for n, r in joined.items():
        results[n] = {**r, "launches": launches[n],
                      "extra_launches": launches[n] - launches[0],
                      "p50_vs_baseline": r["p50_ms"] / base_p50}
        rep.add(f"lastjoin/joins={n}", 1e6 / r["qps"],
                qps=round(r["qps"], 1), p50_ms=round(r["p50_ms"], 3),
                p99_ms=round(r["p99_ms"], 3),
                p50_vs_baseline=round(r["p50_ms"] / base_p50, 3),
                launches=launches[n])
    rep.add("lastjoin/baseline_bracketed", 1e6 / results[0]["qps"],
            qps=round(results[0]["qps"], 1),
            p50_ms=round(base_p50, 3),
            p50_ms_pre=round(base_pre["p50_ms"], 3),
            p50_ms_post=round(base_post["p50_ms"], 3))

    summary = {
        "quick": QUICK,
        "join_counts": list(JOIN_COUNTS),
        "by_joins": {str(n): results[n] for n in JOIN_COUNTS},
        "baseline_bracket": {"pre": base_pre, "post": base_post},
        "p50_ratio_3_vs_0": results[3]["p50_ms"] / base_p50,
        # acceptance views (ISSUE 4)
        "three_joins_within_2_5x": results[3]["p50_ms"] < 2.5 * base_p50,
        "one_extra_launch_per_join": all(
            results[n]["extra_launches"] == n for n in JOIN_COUNTS),
    }
    if not summary["one_extra_launch_per_join"]:
        # launch accounting is structural — a miscount is a bug, not noise
        raise RuntimeError(
            f"per-join launch accounting broke: "
            f"{({n: results[n]['extra_launches'] for n in JOIN_COUNTS})}")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    return summary


if __name__ == "__main__":
    r = Reporter()
    out = run(r)
    print(r.emit())
    print(json.dumps({k: v for k, v in out.items() if k != "by_joins"},
                     indent=1))
