"""Serving latency across a hot-swap redeploy (DESIGN.md §6).

Three measured phases over identical request batches, same engine, same
compiled artifacts (drift-bracketed: a trailing baseline re-measures
phase 1 so machine noise can't masquerade as a swap cost):

1. ``baseline``     — steady-state serving on version 1;
2. ``during_swap``  — a background thread redeploys the query (build +
   pre-warm + atomic swap) mid-phase while the foreground keeps
   requesting through the name-resolved live handle;
3. ``trailing``     — steady-state on version 2 (drift bracket).

Targets: no JIT-compile spike on the serving path (the new version is
pre-warmed before publish — `during_swap` max latency stays within CPU-
contention range of baseline p99, NOT the ~100ms+ of an XLA compile),
every response is served by exactly one version, and the legacy
``Engine.request(name, ...)`` shim stays within noise of the direct
handle path.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import FEATURE_SQL, QUICK, REQ_BATCH, Reporter, \
    build_engine

# Different window sizes -> different plan fingerprint -> the swap takes
# the full build + warm + invalidate path (same aliases, so comparisons
# stay name-compatible).
SQL_V2 = FEATURE_SQL.replace("10 PRECEDING", "12 PRECEDING") \
                    .replace("100 PRECEDING", "80 PRECEDING")


def _pcts(lats_ms: List[float]) -> Dict[str, float]:
    a = np.asarray(lats_ms)
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "max_ms": float(a.max())}


def run(rep: Reporter) -> dict:
    eng, data = build_engine()
    keys, ts, _ = data
    B = 64 if QUICK else REQ_BATCH
    n_batches = 8 if QUICK else 40
    rng = np.random.default_rng(7)
    base_ts = float(ts.max()) + 1.0

    def batch(i):
        ks = rng.choice(keys, B).tolist()
        rts = [base_ts + i] * B
        return ks, rts

    def phase(serve, n, start_offset=0) -> Dict[str, object]:
        lats, versions = [], set()
        for i in range(n):
            ks, rts = batch(start_offset + i)
            t0 = time.perf_counter()
            out = serve(ks, rts)
            lats.append((time.perf_counter() - t0) * 1e3)
            versions.add(getattr(out, "version", 0))
        return {"lats": lats, "versions": sorted(versions)}

    handle_v1 = eng.handle("bench")
    handle_v1.request(*batch(0))                      # compile bucket B

    baseline = phase(handle_v1.request, n_batches)

    # -- swap mid-phase: deploy runs in the background, the foreground
    # resolves the live handle per batch (the shim path), so responses
    # cross the version boundary without ever mixing inside a batch. The
    # phase keeps serving until the swap has landed plus n_batches more,
    # so both sides of the boundary are in the sample.
    swap_wall = {}
    swap_done = threading.Event()

    def swapper():
        time.sleep(0.02)
        t0 = time.perf_counter()
        try:
            eng.deploy("bench", SQL_V2)
        except BaseException as e:       # surface instead of hanging
            swap_wall["error"] = repr(e)
        swap_wall["s"] = time.perf_counter() - t0
        swap_done.set()

    th = threading.Thread(target=swapper)
    th.start()
    during = {"lats": [], "versions": set()}
    i, post_swap = 0, 0
    while post_swap < n_batches and i < 500 * n_batches:
        ks, rts = batch(n_batches + i)
        t0 = time.perf_counter()
        out = eng.request("bench", ks, rts)
        during["lats"].append((time.perf_counter() - t0) * 1e3)
        during["versions"].add(out.version)
        if swap_done.is_set():
            post_swap += 1
        i += 1
    th.join()
    during["versions"] = sorted(during["versions"])

    handle_v2 = eng.handle("bench")
    trailing = phase(handle_v2.request, n_batches,
                     start_offset=2 * n_batches)

    # -- old string API vs handle path (same live handle, same batches)
    m = 2 * n_batches                    # cheap (warm) — keep noise down
    shim = phase(lambda ks, rts: eng.request("bench", ks, rts), m,
                 start_offset=3 * n_batches)
    direct = phase(handle_v2.request, m, start_offset=3 * n_batches + m)

    b, d, t = _pcts(baseline["lats"]), _pcts(during["lats"]), \
        _pcts(trailing["lats"])
    steady_p99 = max(b["p99_ms"], t["p99_ms"])
    spike_ratio = d["max_ms"] / steady_p99 if steady_p99 else float("inf")
    shim_ratio = (np.mean(shim["lats"]) / np.mean(direct["lats"])
                  if np.mean(direct["lats"]) else float("inf"))

    # hard tripwires — this bench is CI's serving-path regression guard,
    # so breakage must FAIL the job, not upload plausible numbers:
    if "error" in swap_wall:
        raise RuntimeError(f"hot-swap redeploy failed mid-run: "
                           f"{swap_wall['error']}")
    if during["versions"] != [1, 2]:
        raise RuntimeError(
            f"swap not observed on the serving path: versions served "
            f"during swap = {during['versions']} (want [1, 2])")
    # a JIT compile on the hot path blocks a request for ~the whole
    # build wall; background-build CPU contention measures a small
    # fraction of it (<=~0.25 observed). Self-scaling with machine
    # speed, unlike a ratio against the (noisy, tiny) steady p99.
    swap_s = swap_wall.get("s") or 0.0
    if swap_s and d["max_ms"] / 1e3 > 0.7 * swap_s:
        raise RuntimeError(
            f"compile-spike tripwire: during-swap max {d['max_ms']:.1f}ms "
            f"~= the {swap_s * 1e3:.0f}ms redeploy build itself — a "
            f"request paid the compile on the serving path")

    res = {
        "baseline": b, "during_swap": d, "trailing": t,
        "swap_wall_s": swap_wall.get("s"),
        "versions_during_swap": during["versions"],
        "spike_ratio_vs_steady_p99": round(spike_ratio, 2),
        "shim_over_handle_mean_ratio": round(float(shim_ratio), 3),
        "invalidations": eng.cache.stats.invalidations,
    }
    rep.add("hotswap/baseline", b["p50_ms"] * 1e3 / B, **b)
    rep.add("hotswap/during_swap", d["p50_ms"] * 1e3 / B, **d,
            versions=during["versions"],
            spike_ratio=res["spike_ratio_vs_steady_p99"])
    rep.add("hotswap/trailing", t["p50_ms"] * 1e3 / B, **t)
    rep.add("hotswap/shim_vs_handle", 0.0,
            ratio=res["shim_over_handle_mean_ratio"])
    eng.close()
    return res


if __name__ == "__main__":
    r = Reporter()
    out = run(r)
    print(r.emit())
    import json
    print(json.dumps(out, indent=1))
