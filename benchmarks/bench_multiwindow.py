"""Fused multi-window execution: QPS / p50 / p99 vs number of distinct
window specs per deployment, single-scan fused path vs per-group launches.

The paper attributes its largest gain to query-plan optimization; the
OpenMLDB system work makes multi-window parallel execution with shared
scans a headline item. This bench measures our form of it: a deployment
with S distinct plain window frames (each carrying SUM/AVG/LAST — LAST
pins the frame to the raw-scan path, so the sweep isolates the fusion
axis from pre-aggregation) served with ``fuse_windows`` on vs off.

Drift bracketing (the 2-core CI host swings ±2x run-to-run): for every
spec count the per-group baseline is measured BEFORE and AFTER the fused
phase on the same warmed engines, and the fused numbers are compared
against the MEAN of the two brackets — machine drift cancels right where
the comparison happens instead of being "tolerated" by skipping it.

Emits ``experiments/BENCH_multiwindow.json`` (machine-readable trajectory
for the perf history) in addition to the canonical Reporter rows. Quick
mode (``REPRO_BENCH_QUICK`` / ``run.py --quick``) shrinks the sweep.
"""
from __future__ import annotations

import json
import os

from repro.core.optimizer import OptFlags

from benchmarks.common import QUICK, Reporter, build_engine, replay

SPEC_COUNTS = (1, 4) if QUICK else (1, 2, 4, 8)
# quick/CI smoke numbers go to an ignored path — they must never clobber
# the committed full-mode trajectory file
OUT_PATH = os.path.join(
    "experiments",
    "bench_multiwindow_quick.json" if QUICK else "BENCH_multiwindow.json")


def make_sql(n_specs: int) -> str:
    """n distinct ROWS frames, each with SUM/AVG/LAST over it."""
    selects, windows = [], []
    for i in range(1, n_specs + 1):
        selects += [f"SUM(amount) OVER w{i} AS s{i}",
                    f"AVG(amount) OVER w{i} AS a{i}",
                    f"LAST(amount) OVER w{i} AS l{i}"]
        windows.append(
            f"w{i} AS (PARTITION BY user ORDER BY ts "
            f"ROWS BETWEEN {8 * i + 2} PRECEDING AND CURRENT ROW)")
    return ("SELECT " + ", ".join(selects) + " FROM events WINDOW "
            + ", ".join(windows))


def run(rep: Reporter) -> dict:
    results = {}
    for n in SPEC_COUNTS:
        sql = make_sql(n)
        eng_f, data = build_engine(OptFlags(fuse_windows=True), sql=sql)
        eng_p, _ = build_engine(OptFlags(fuse_windows=False), sql=sql)
        launches_f = eng_f.handle("bench").phys.n_kernel_launches
        launches_p = eng_p.handle("bench").phys.n_kernel_launches

        # bracket: pergroup BEFORE and AFTER the fused phase; both engines
        # keep their compiled executables across phases (replay warms)
        r_p1 = replay(eng_p, data)
        r_f = replay(eng_f, data)
        r_p2 = replay(eng_p, data)
        p50_pg = 0.5 * (r_p1["p50_batch_ms"] + r_p2["p50_batch_ms"])
        p99_pg = 0.5 * (r_p1["p99_batch_ms"] + r_p2["p99_batch_ms"])
        qps_pg = 0.5 * (r_p1["qps"] + r_p2["qps"])
        eng_f.close()
        eng_p.close()

        row = {
            "n_specs": n,
            "launches_fused": launches_f,
            "launches_pergroup": launches_p,
            "fused": {"qps": r_f["qps"], "p50_ms": r_f["p50_batch_ms"],
                      "p99_ms": r_f["p99_batch_ms"]},
            "pergroup_bracketed": {"qps": qps_pg, "p50_ms": p50_pg,
                                   "p99_ms": p99_pg,
                                   "p50_ms_pre": r_p1["p50_batch_ms"],
                                   "p50_ms_post": r_p2["p50_batch_ms"]},
            "p50_speedup": p50_pg / r_f["p50_batch_ms"],
            "fused_p50_below_pergroup":
                r_f["p50_batch_ms"] < p50_pg,
        }
        results[n] = row
        rep.add(f"multiwindow/specs={n}", 1e6 / r_f["qps"],
                qps_fused=round(r_f["qps"], 1),
                qps_pergroup=round(qps_pg, 1),
                p50_fused_ms=round(r_f["p50_batch_ms"], 3),
                p50_pergroup_ms=round(p50_pg, 3),
                p50_speedup=round(row["p50_speedup"], 3),
                launches=f"{launches_f}v{launches_p}")

    summary = {
        "spec_counts": list(SPEC_COUNTS),
        "quick": QUICK,
        "by_specs": results,
        # acceptance view: fused wins p50 at every swept count >= 4
        "fused_wins_at_4plus": all(
            r["fused_p50_below_pergroup"]
            for k, r in results.items() if k >= 4),
        "single_launch_at_4plus": all(
            r["launches_fused"] == 1
            for k, r in results.items() if k >= 4),
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    return summary
