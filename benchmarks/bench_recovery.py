"""Kill-to-recovery MTTR: WAL + warm standby vs cold respawn (§12).

Chaos acceptance for the durability tier. One subprocess shard worker is
SIGKILLed under a live deployment and the bench measures, per round:

* ``t_available_s`` — kill → first response with **no SHED rows** (the
  degradation ladder answering: DEGRADED from the stale tier counts,
  full SHED does not);
* ``t_parity_s``    — kill → first response that is all ``STATUS_OK``
  AND bit-identical to the pre-kill reference frame (data fully
  restored, the real MTTR).

Two configs over identical seeded workloads, interleaved per round so
machine drift brackets both:

* ``baseline`` — PR-7 semantics: no WAL, no standby pool, no stale
  tier. Recovery = cold worker spawn (multi-second jax import) +
  catalog/deployment replay; the shard's partitioned data is LOST, so
  the bench plays the producer and re-sends the dead shard's events
  before parity can return.
* ``durable``  — this PR: per-shard write-ahead ingest log + one warm
  standby worker + stale-tier cache. Recovery is automatic: adopt a
  pre-warmed worker (ms), replay DDL, then re-scatter the dead shard's
  WAL through the live route table.

Acceptance (ISSUE 8): median kill-to-parity MTTR must be **>= 2x
better** with WAL+standby than baseline (``meets_2x`` in the JSON; the
standby pool alone saves the ~5 s import, the WAL removes the
producer-replay round-trip). Emits ``experiments/BENCH_recovery.json``
(quick mode writes an ignored ``_quick`` path).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import QUICK
from repro.core.results import STATUS_OK, STATUS_SHED
from repro.featurestore.table import TableSchema
from repro.shard import ShardConfig, ShardedEngine

OUT_PATH = os.path.join(
    "experiments",
    "bench_recovery_quick.json" if QUICK else "BENCH_recovery.json")

SQL = """SELECT SUM(amount) OVER w AS s, COUNT(amount) OVER w AS c,
AVG(amount) OVER w AS a
FROM events
WINDOW w AS (PARTITION BY user ORDER BY ts
             ROWS BETWEEN 10 PRECEDING AND CURRENT ROW)"""
SCHEMA = TableSchema("events", key_col="user", ts_col="ts",
                     value_cols=("amount", "mkey"))

N_EVENTS = 200 if QUICK else 600
N_KEYS = 8
N_ROUNDS = 1 if QUICK else 3
PARITY_TIMEOUT_S = 120.0


def _events(seed: int):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, N_KEYS, N_EVENTS)
    ts = np.sort(rng.uniform(0, 1000.0, N_EVENTS)).astype(np.float32)
    rows = np.stack(
        [rng.normal(size=N_EVENTS),
         rng.integers(0, 4, N_EVENTS).astype(np.float64)],
        -1).astype(np.float32)
    return keys, ts, rows


def _measure_round(durable: bool, seed: int) -> Dict[str, float]:
    keys, ts, rows = _events(seed)
    wal_dir = tempfile.mkdtemp(prefix="bench-recovery-wal-") \
        if durable else None
    cfg = ShardConfig(
        n_shards=2,
        wal_dir=wal_dir,
        standby_workers=1 if durable else 0,
        degraded_cache_keys=4096 if durable else 0)
    se = ShardedEngine(cfg, backend="process")
    try:
        se.create_table(SCHEMA, max_keys=64, capacity=64, bucket_size=8)
        pipe = se.attach_stream("events", flush_interval_s=0.05)
        pipe.push_batch(keys, ts, rows)
        pipe.flush()
        se.deploy("q", SQL)
        rk, rt = list(range(N_KEYS)), [2000.0] * N_KEYS
        ref = se.request("q", rk, rt)
        assert (ref.status == STATUS_OK).all()

        victim = 1
        # keys the dead shard owns — the baseline producer re-sends these
        owners = se.owners_of(np.asarray(keys))
        vmask = owners == victim
        restarts0 = se.worker_restarts
        os.kill(se.shards[victim].proc.pid, signal.SIGKILL)
        t0 = time.perf_counter()

        t_avail = None
        fr = None
        reingested = not durable and not vmask.any()
        deadline = t0 + PARITY_TIMEOUT_S
        while time.perf_counter() < deadline:
            try:
                fr = se.request("q", rk, rt)
            except Exception:
                time.sleep(0.02)
                continue
            st = np.asarray(fr.status)
            if t_avail is None and not (st == STATUS_SHED).any():
                t_avail = time.perf_counter() - t0
            if not durable and not reingested \
                    and se.worker_restarts > restarts0 \
                    and se.shards[victim].ready:
                # producer-side replay: without a WAL the shard's events
                # only exist at the source — re-send them (part of the
                # baseline's MTTR, which is the point). The push can
                # still race death-detection of the SIGKILLed worker;
                # just retry next poll
                try:
                    pipe.push_batch(keys[vmask], ts[vmask], rows[vmask])
                    pipe.flush()
                    reingested = True
                except Exception:
                    pass
            if (st == STATUS_OK).all() and all(
                    np.array_equal(np.asarray(ref[c]), np.asarray(fr[c]))
                    for c in ref.columns):
                t_parity = time.perf_counter() - t0
                return {"t_available_s": t_avail
                        if t_avail is not None else t_parity,
                        "t_parity_s": t_parity,
                        "adopted": float(se.backend.recovery_stats.get(
                            "last_adopted", 0.0)),
                        "replayed_events": float(
                            se.recovery_stats["wal_replayed_events"])}
            time.sleep(0.02)
        raise RuntimeError(
            f"{'durable' if durable else 'baseline'} round never reached "
            f"parity within {PARITY_TIMEOUT_S}s; last status "
            f"{np.asarray(fr.status).tolist() if fr is not None else '?'}")
    finally:
        se.close()
        if wal_dir is not None:
            shutil.rmtree(wal_dir, ignore_errors=True)


def run(rep) -> dict:
    rounds: List[Dict[str, Dict[str, float]]] = []
    for r in range(N_ROUNDS):
        # interleave so drift brackets both configs within each round
        base = _measure_round(durable=False, seed=100 + r)
        dur = _measure_round(durable=True, seed=100 + r)
        rounds.append({"baseline": base, "durable": dur})
        print(f"# recovery round {r}: baseline parity "
              f"{base['t_parity_s']:.2f}s, durable parity "
              f"{dur['t_parity_s']:.2f}s", flush=True)

    med = lambda xs: float(np.median(xs))  # noqa: E731
    base_parity = med([r["baseline"]["t_parity_s"] for r in rounds])
    dur_parity = med([r["durable"]["t_parity_s"] for r in rounds])
    dur_avail = med([r["durable"]["t_available_s"] for r in rounds])
    speedup = base_parity / dur_parity if dur_parity > 0 else float("inf")

    summary = {
        "quick": QUICK,
        "n_rounds": N_ROUNDS,
        "baseline_parity_s_median": base_parity,
        "durable_parity_s_median": dur_parity,
        "durable_available_s_median": dur_avail,
        "mttr_speedup": speedup,
        "meets_2x": bool(speedup >= 2.0),
        "per_round": rounds,
    }
    rep.add("recovery_baseline_parity", base_parity * 1e6,
            mttr_s=round(base_parity, 3))
    rep.add("recovery_durable_parity", dur_parity * 1e6,
            mttr_s=round(dur_parity, 3), speedup=round(speedup, 2),
            meets_2x=summary["meets_2x"])
    os.makedirs("experiments", exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"# wrote {OUT_PATH} (speedup {speedup:.2f}x, "
          f"meets_2x={summary['meets_2x']})", flush=True)
    return summary


if __name__ == "__main__":
    from benchmarks.common import Reporter
    r = Reporter()
    run(r)
    print(r.emit())
