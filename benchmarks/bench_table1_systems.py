"""Paper Table 1: systems comparison — our measured engine-variant numbers
side by side with the paper's published reference points.

The baselines are execution-model emulations (DESIGN.md §8.2): we validate
RELATIVE standings (OpenMLDB-style execution at the top, row interpreters
at the bottom, microbatch in between), not absolute QPS of foreign DBMSes.
"""
from __future__ import annotations

from repro.core.baselines import PAPER_TABLE1

from benchmarks.common import Reporter


def run(rep: Reporter, fig1_results: dict) -> dict:
    mapping = {                      # paper system -> our execution model
        "PostgreSQL": "row_interpreter",
        "MySQL": "row_interpreter",
        "SparkSQL": "microbatch",
        "ClickHouse": "columnar_scan",
        "OpenMLDB(paper)": "openmldb",
    }
    for system, (paper_qps, (lo, hi)) in PAPER_TABLE1.items():
        ours = fig1_results.get(mapping.get(system, ""), None)
        rep.add(f"table1/{system}", 0.0,
                paper_qps=paper_qps, paper_latency_ms=f"{lo}-{hi}",
                our_profile=mapping.get(system, "-"),
                our_qps=round(ours["qps"], 1) if ours else None,
                our_p50_req_ms=round(ours["p50_req_ms"], 4)
                if ours else None)
    # tier ordering check (execution models, not DBMS brands): specialised
    # engine > vectorized generic engines (ClickHouse/SparkSQL tier) >
    # row interpreters (PostgreSQL/MySQL tier) — the paper's Table-1
    # structure.
    top = fig1_results["openmldb"]["qps"]
    mid = max(fig1_results["columnar_scan"]["qps"],
              fig1_results["microbatch"]["qps"])
    low = fig1_results["row_interpreter"]["qps"]
    ok = top > mid > low
    rep.add("table1/tier_ordering_matches_paper", 0.0, ok=bool(ok),
            specialised=round(top, 1), vectorized_generic=round(mid, 1),
            row_interpreter=round(low, 1))
    return {"ordering_ok": ok}
