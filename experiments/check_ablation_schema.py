"""Regen guard for the committed ablation artifact.

    PYTHONPATH=src python experiments/check_ablation_schema.py

``experiments/ABLATION_profiles.json`` is committed output of
``experiments/ablation_from_profiles.py``. This check keeps the two
from drifting apart without re-running the (slow) profiling itself: it
validates that the committed artifact still has exactly the schema the
generator produces — same top-level keys, same ablation axes as the
live ``bench_fig2_ablation.AXES`` registry, every per-axis record
carrying the full measured decomposition, and the normalized
contribution shares summing to ~100. A PR that adds an ablation axis,
renames a field, or hand-edits the JSON fails here until the artifact
is regenerated.

Exit code 0 = in sync; 1 = schema drift (each violation printed).
"""
from __future__ import annotations

import json
import math
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

ARTIFACT = os.path.join(_HERE, "ABLATION_profiles.json")

TOP_KEYS = {"quick", "full", "explain_analyze_full", "axes",
            "paper_bands", "method"}
FULL_KEYS = {"qps", "requests", "serve_us_per_req", "exec_us_per_req",
             "host_us_per_req", "plan_us_per_req", "ops_us_per_req"}
AXIS_KEYS = {"serve_us_per_req", "baseline_us_per_req",
             "added_us_per_req", "added_by_stage", "slowdown",
             "contribution_pct"}
STAGE_KEYS = {"exec", "host", "plan"}


def check() -> list:
    errs = []
    try:
        with open(ARTIFACT) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {ARTIFACT}: {e}"]

    if set(doc) != TOP_KEYS:
        errs.append(f"top-level keys {sorted(doc)} != {sorted(TOP_KEYS)}")
    if doc.get("quick") is not False:
        errs.append("committed artifact must come from a FULL run "
                    f"(quick={doc.get('quick')!r})")

    # the axis set must match the generator's live registry
    from benchmarks.bench_fig2_ablation import AXES
    committed = set(doc.get("axes", {}))
    if committed != set(AXES):
        errs.append(f"axes {sorted(committed)} != generator registry "
                    f"{sorted(AXES)} — re-run ablation_from_profiles.py")

    full = doc.get("full", {})
    if set(full) != FULL_KEYS:
        errs.append(f"full keys {sorted(full)} != {sorted(FULL_KEYS)}")

    total_pct = 0.0
    for name, ax in doc.get("axes", {}).items():
        if set(ax) != AXIS_KEYS:
            errs.append(f"axis {name!r} keys {sorted(ax)} "
                        f"!= {sorted(AXIS_KEYS)}")
            continue
        if set(ax["added_by_stage"]) != STAGE_KEYS:
            errs.append(f"axis {name!r} added_by_stage keys "
                        f"{sorted(ax['added_by_stage'])} "
                        f"!= {sorted(STAGE_KEYS)}")
        for k in AXIS_KEYS - {"added_by_stage"}:
            if not isinstance(ax[k], (int, float)) or not math.isfinite(ax[k]):
                errs.append(f"axis {name!r} field {k!r} is not finite "
                            f"({ax[k]!r})")
        total_pct += float(ax.get("contribution_pct", 0.0))

    if doc.get("axes") and abs(total_pct - 100.0) > 1.0:
        errs.append(f"contribution_pct sums to {total_pct:.2f}, "
                    f"expected ~100 (normalized shares)")
    if not isinstance(doc.get("explain_analyze_full"), str) \
            or "EXPLAIN ANALYZE" not in doc.get("explain_analyze_full", ""):
        errs.append("explain_analyze_full is not an EXPLAIN ANALYZE "
                    "rendering")
    return errs


def main() -> int:
    errs = check()
    if errs:
        print(f"ABLATION_profiles.json schema drift "
              f"({len(errs)} violation(s)):")
        for e in errs:
            print(f"  - {e}")
        return 1
    print("ABLATION_profiles.json matches the generator schema")
    return 0


if __name__ == "__main__":
    sys.exit(main())
