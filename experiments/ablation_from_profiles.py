"""Regenerate the paper's speedup-attribution table from LIVE profiles.

    PYTHONPATH=src python experiments/ablation_from_profiles.py [--quick]

The paper attributes its headline result 35% to query-plan
optimization, 25% to caching, 20% to parallelism. Figure-2's bench
(``benchmarks.bench_fig2_ablation``) reproduces that with leave-one-out
QPS ratios — a black-box view. This script is the white-box
counterpart the obs tier makes possible: each leave-one-out
configuration serves the same workload and the attribution is computed
from the runtime operator profiler's MEASURED per-request serve
decomposition (the same data ``EXPLAIN ANALYZE`` renders — exec split
per operator, host residual, amortized plan/compile), not from
throughput alone.

For each ablation axis the report shows (a) how much per-request serve
time the optimization removes (measured, not modeled), (b) which
decomposition stage the removal comes from (exec vs host vs plan —
e.g. disabling the plan cache shows up as plan/compile seconds, while
disabling pre-aggregation shows up as scan-operator exec seconds), and
(c) the normalized contribution share, the live-profile analogue of
the paper's 35/25/20 split. Writes
``experiments/ABLATION_profiles.json`` and prints the table.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def profile_config(flags, sql=None, *, batch, n_batches):
    """Serve the standard workload under ``flags``; return the
    profiler's measured per-request decomposition + the EXPLAIN ANALYZE
    text."""
    from benchmarks.common import build_engine, replay
    kw = {} if sql is None else {"sql": sql}
    eng, data = build_engine(flags, **kw)
    replay(eng, data, batch=batch, n_batches=1)      # compiles outside
    eng.drain_profile_observations("bench")
    # reset the totals window: snapshot() is cumulative, so profile a
    # fresh engine-lifetime interval by diffing against this baseline
    base = eng.profiler.snapshot("bench") or {}
    r = replay(eng, data, batch=batch, n_batches=n_batches, warm=False)
    prof = eng.profiler.snapshot("bench")
    analyze = eng.explain_analyze("bench")
    eng.close()
    reqs = prof["requests"] - base.get("requests", 0)
    out = {"qps": r["qps"], "requests": reqs,
           "explain_analyze": analyze}
    for k in ("serve_s", "exec_s", "host_s", "plan_s"):
        out[f"{k[:-2]}_us_per_req"] = \
            (prof[k] - base.get(k, 0.0)) / max(reqs, 1) * 1e6
    ops = {}
    for op, row in prof["ops"].items():
        sec = row["seconds"] - base.get("ops", {}).get(
            op, {}).get("seconds", 0.0)
        ops[op] = sec / max(reqs, 1) * 1e6
    out["ops_us_per_req"] = ops
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-smoke sizes (tripwire numbers only)")
    ap.add_argument("--out",
                    default=os.path.join(_HERE, "ABLATION_profiles.json"))
    args = ap.parse_args(argv)
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    from benchmarks.bench_fig2_ablation import AXES, _axis_sql
    from benchmarks.common import QUICK
    from repro.core.optimizer import OptFlags

    batch, n_batches = (64, 4) if QUICK else (256, 12)
    base_flags = OptFlags()
    full = profile_config(base_flags, batch=batch, n_batches=n_batches)

    rows = {}
    for name, overrides in AXES.items():
        if name == "parallel_vectorized" and not QUICK:
            nb = 3                           # row-at-a-time is ~100x
        else:
            nb = n_batches
        sql = _axis_sql(name)
        ref = full if sql is None else profile_config(
            base_flags, sql, batch=batch, n_batches=nb)
        ablated = profile_config(
            dataclasses.replace(base_flags, **overrides), sql,
            batch=batch, n_batches=nb)
        added = ablated["serve_us_per_req"] - ref["serve_us_per_req"]
        rows[name] = {
            "serve_us_per_req": ablated["serve_us_per_req"],
            "baseline_us_per_req": ref["serve_us_per_req"],
            "added_us_per_req": added,
            # which measured stage the removed time came from
            "added_by_stage": {
                st: ablated[f"{st}_us_per_req"] - ref[f"{st}_us_per_req"]
                for st in ("exec", "host", "plan")},
            "slowdown": (ablated["serve_us_per_req"]
                         / max(ref["serve_us_per_req"], 1e-9)),
        }

    total = sum(max(r["added_us_per_req"], 0.0) for r in rows.values()) \
        or 1.0
    for r in rows.values():
        r["contribution_pct"] = \
            100.0 * max(r["added_us_per_req"], 0.0) / total

    report = {
        "quick": QUICK,
        "full": {k: v for k, v in full.items()
                 if k != "explain_analyze"},
        "explain_analyze_full": full["explain_analyze"],
        "axes": rows,
        "paper_bands": {"query_plan_opt": "30-35%",
                        "caching_materialization": "15-25%",
                        "parallel_processing": "20-25%",
                        "resource_management": "~10%"},
        "method": "leave-one-out serve-time deltas measured by the "
                  "runtime operator profiler (us/request, profiled "
                  "interval only), normalized to 100%",
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)

    width = max(len(n) for n in rows)
    print(f"# attribution from live profiles "
          f"(full: {full['serve_us_per_req']:.1f} us/req serve)")
    print(f"{'axis':<{width}}  {'share':>6}  {'added us/req':>12}  "
          f"{'slowdown':>8}  dominant stage")
    for n, r in sorted(rows.items(),
                       key=lambda kv: -kv[1]["contribution_pct"]):
        dom = max(r["added_by_stage"],
                  key=lambda s: r["added_by_stage"][s])
        print(f"{n:<{width}}  {r['contribution_pct']:>5.1f}%  "
              f"{r['added_us_per_req']:>12.1f}  "
              f"{r['slowdown']:>7.2f}x  {dom}")
    print(f"# paper bands: plan 30-35% / caching 15-25% / "
          f"parallel 20-25% / resource ~10%")
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
